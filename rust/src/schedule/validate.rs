//! Structural validation of communication schedules.
//!
//! A schedule admitted here is guaranteed to be *executable and
//! deterministic*: every op's chunks fit their tensors, every dependency
//! resolves to an existing op, peers are in range, the global
//! happens-before relation (per-rank program order ∪ cross-rank deps) is
//! acyclic (deadlock-free), no two unordered accesses race on overlapping
//! regions — write-write *and* read-write, either would make the two exec
//! engines diverge — and any rank that assembles a full tensor does so as
//! an exact tiling ([`check_covers`] wired into [`validate`] — the classic
//! gather off-by-one where shard regions overlap by a row while summing to
//! the tensor size is rejected here instead of corrupting numerics
//! silently).
//!
//! The happens-before graphs and reachability closure are built by
//! [`crate::analysis::hb`], shared with the multi-rule static analyzer —
//! one builder, one semantics. `validate` stays a cheap first-error gate;
//! [`crate::analysis::run`] reports *every* violation with witnesses.

use std::collections::{HashMap, HashSet};

use crate::analysis::hb::{OpGraph, Reach};
use crate::chunk::{Region, TensorId};
use crate::error::{Error, Result};
use crate::schedule::{CommOp, CommSchedule, OpRef};

/// Validate a schedule; returns `Ok(())` or the first violation found.
pub fn validate(sched: &CommSchedule) -> Result<()> {
    if sched.per_rank.len() != sched.world {
        return Err(Error::Schedule(format!(
            "per_rank has {} entries for world {}",
            sched.per_rank.len(),
            sched.world
        )));
    }
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        for (index, op) in ops.iter().enumerate() {
            let at = format!("op ({rank},{index})");
            // chunk bounds
            op.consumed_chunk()
                .validate(&sched.tensors)
                .map_err(|e| Error::Schedule(format!("{at}: src {e}")))?;
            op.produced_chunk()
                .validate(&sched.tensors)
                .map_err(|e| Error::Schedule(format!("{at}: dst {e}")))?;
            // element-count match between src and dst
            if op.consumed_chunk().region.elems() != op.produced_chunk().region.elems() {
                return Err(Error::Schedule(format!(
                    "{at}: src/dst element counts differ ({} vs {})",
                    op.consumed_chunk().region.elems(),
                    op.produced_chunk().region.elems()
                )));
            }
            // peer / group ranks in range
            match op {
                CommOp::P2p { peer, .. } => {
                    if *peer >= sched.world {
                        return Err(Error::Schedule(format!("{at}: peer {peer} oob")));
                    }
                    if *peer == rank {
                        return Err(Error::Schedule(format!(
                            "{at}: P2P with self (use LocalCopy)"
                        )));
                    }
                }
                CommOp::Collective { ranks, .. } => {
                    let set: HashSet<_> = ranks.iter().collect();
                    if set.len() != ranks.len() {
                        return Err(Error::Schedule(format!("{at}: duplicate group ranks")));
                    }
                    if ranks.iter().any(|&r| r >= sched.world) {
                        return Err(Error::Schedule(format!("{at}: group rank oob")));
                    }
                    if !ranks.contains(&rank) {
                        return Err(Error::Schedule(format!(
                            "{at}: issuing rank not in collective group"
                        )));
                    }
                }
                CommOp::LocalCopy { .. } => {}
            }
            // dep resolvability
            for d in op.deps() {
                if d.rank >= sched.world {
                    return Err(Error::Schedule(format!("{at}: dep rank {} oob", d.rank)));
                }
                if d.index >= sched.per_rank[d.rank].len() {
                    return Err(Error::Schedule(format!(
                        "{at}: dep ({}, {}) references missing op",
                        d.rank, d.index
                    )));
                }
            }
        }
    }
    let order = topo_order(sched)?;
    check_write_hazards(sched, &order)?;
    check_gather_destinations(sched)
}

/// Deadlock-freedom: the relation {program order on each rank} ∪ {dep edges}
/// must be a DAG. Returns a topological order of all ops when acyclic; on a
/// cycle, the error carries the full certificate path (same one
/// [`crate::analysis`] reports as rule `SY-E003`).
pub fn topo_order(sched: &CommSchedule) -> Result<Vec<OpRef>> {
    match OpGraph::issue_order(sched).topo_refs() {
        Ok(order) => Ok(order),
        Err(cycle) => {
            let path: Vec<String> =
                cycle.iter().map(|o| format!("({},{})", o.rank, o.index)).collect();
            Err(Error::Schedule(format!(
                "dependency cycle (deadlock): {} -> (back to start)",
                path.join(" -> ")
            )))
        }
    }
}

/// Race detection: two ops accessing overlapping regions of the same
/// tensor on the same rank must be ordered by the schedule's *apply-order*
/// happens-before relation ([`OpGraph::apply_order`] has the full
/// asynchronous-issue rationale). Two hazard classes are rejected:
///
/// * **write-write** — unless both are reduce ops, whose contributions
///   commute semantically (the exec layer's `plan_prep` serializes them
///   canonically for f32 bit-stability);
/// * **read-write** — an op sourcing a region unordered w.r.t. an op
///   writing an overlapping region reads either pre- or post-write bytes
///   depending on timing.
///
/// Either unordered pair means the engines (or two runs of the parallel
/// engine) may legitimately diverge; such plans are rejected as
/// nondeterministic-by-construction.
fn check_write_hazards(sched: &CommSchedule, order: &[OpRef]) -> Result<()> {
    let g = OpGraph::apply_order(sched);
    if g.n < 2 {
        return Ok(());
    }
    // The caller's order is topological for the *issue* graph; apply order
    // is a subgraph of its transitive closure, so the order remains valid.
    let ids: Vec<usize> = order.iter().map(|o| g.id(*o)).collect();
    let reach = Reach::build(&g, &ids);

    // Accesses grouped by (memory rank, tensor):
    // (graph node id, op ref, region, is-reduce).
    type AccessList<'a> = Vec<(usize, OpRef, &'a Region, bool)>;
    let mut writes: HashMap<(usize, TensorId), AccessList<'_>> = HashMap::new();
    let mut reads: HashMap<(usize, TensorId), AccessList<'_>> = HashMap::new();
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        for (index, op) in ops.iter().enumerate() {
            let reduce = match op {
                CommOp::P2p { reduce, .. } => *reduce,
                CommOp::LocalCopy { .. } => false,
                CommOp::Collective { .. } => continue, // abstract until lowering
            };
            let opref = OpRef { rank, index };
            let node = g.id(opref);
            writes
                .entry((op.dst_rank(rank), op.produced_chunk().tensor))
                .or_default()
                .push((node, opref, &op.produced_chunk().region, reduce));
            reads
                .entry((op.src_rank(rank), op.consumed_chunk().tensor))
                .or_default()
                .push((node, opref, &op.consumed_chunk().region, false));
        }
    }
    let name_of = |tensor: TensorId| {
        sched
            .tensors
            .get(tensor)
            .map(|d| d.name.clone())
            .unwrap_or_else(|_| format!("{tensor:?}"))
    };
    for ((mem, tensor), writers) in &writes {
        for (i, a) in writers.iter().enumerate() {
            for b in writers.iter().skip(i + 1) {
                if (a.3 && b.3) || !a.2.intersects(b.2) {
                    continue;
                }
                if !reach.ordered(a.0, b.0) {
                    return Err(Error::Schedule(format!(
                        "unordered overlapping writes (race) to `{}` on rank {mem}: \
                         ops ({},{}) and ({},{}) write intersecting regions with no \
                         dependency path between them",
                        name_of(*tensor),
                        a.1.rank,
                        a.1.index,
                        b.1.rank,
                        b.1.index
                    )));
                }
            }
        }
        let Some(readers) = reads.get(&(*mem, *tensor)) else { continue };
        for w in writers {
            for r in readers {
                if r.1 == w.1 || !r.2.intersects(w.2) {
                    continue;
                }
                if !reach.ordered(r.0, w.0) {
                    return Err(Error::Schedule(format!(
                        "unordered read-write overlap (race) on `{}` rank {mem}: op \
                         ({},{}) reads a region that op ({},{}) writes, with no \
                         dependency path between them",
                        name_of(*tensor),
                        r.1.rank,
                        r.1.index,
                        w.1.rank,
                        w.1.index
                    )));
                }
            }
        }
    }
    Ok(())
}

/// Gather-destination coverage: when a rank's incoming writes plus the
/// regions it owns at the start (approximated as the distinct regions it
/// *sources* without having received them) sum to exactly the tensor size —
/// i.e. the rank appears to assemble the whole tensor, as every AllGather
/// destination does — the assembly must be an exact tiling per
/// [`check_covers`]. Partial-transfer plans (AllToAll, sub-tensor staging)
/// never sum to the full size and are skipped.
fn check_gather_destinations(sched: &CommSchedule) -> Result<()> {
    // One pass over the ops, grouping distinct regions by (tensor, rank).
    let mut received: HashMap<(TensorId, usize), Vec<&Region>> = HashMap::new();
    let mut sourced: HashMap<(TensorId, usize), Vec<&Region>> = HashMap::new();
    for (owner, ops) in sched.per_rank.iter().enumerate() {
        for op in ops {
            let CommOp::P2p { reduce: false, .. } = op else { continue };
            let rec = received
                .entry((op.produced_chunk().tensor, op.dst_rank(owner)))
                .or_default();
            let r = &op.produced_chunk().region;
            if !rec.contains(&r) {
                rec.push(r);
            }
            let src = sourced
                .entry((op.consumed_chunk().tensor, op.src_rank(owner)))
                .or_default();
            let s = &op.consumed_chunk().region;
            if !src.contains(&s) {
                src.push(s);
            }
        }
    }
    for (tensor, decl) in sched.tensors.iter() {
        let total = decl.elems();
        for rank in 0..sched.world {
            let empty = Vec::new();
            let rec = received.get(&(tensor, rank)).unwrap_or(&empty);
            let src = sourced.get(&(tensor, rank)).unwrap_or(&empty);
            // Regions the rank sends without first receiving them are (an
            // approximation of) its initial ownership; forwarded regions
            // (ring hops) are contained in a received region and drop out.
            let mut regions: Vec<Region> = rec.iter().map(|r| (*r).clone()).collect();
            for &s in src {
                if !rec.iter().any(|r| r.contains(s)) && !regions.contains(s) {
                    regions.push(s.clone());
                }
            }
            let sum: usize = regions.iter().map(|r| r.elems()).sum();
            if sum == total && !regions.is_empty() && !check_covers(&decl.shape, &regions) {
                return Err(Error::Schedule(format!(
                    "gather destination: rank {rank} assembles tensor `{}` from \
                     regions that are not an exact tiling (overlap or gap despite \
                     summing to the tensor size)",
                    decl.name
                )));
            }
        }
    }
    Ok(())
}

/// Do `regions` tile `shape` exactly — full coverage, no overlap?
///
/// Used to check that collective templates account for every element
/// (an AllGather whose shards miss a row is silently wrong otherwise).
pub fn check_covers(shape: &[usize], regions: &[Region]) -> bool {
    let total: usize = shape.iter().product();
    let sum: usize = regions.iter().map(|r| r.elems()).sum();
    if sum != total {
        return false;
    }
    for r in regions {
        if !r.fits(shape) {
            return false;
        }
    }
    for (i, a) in regions.iter().enumerate() {
        for b in regions.iter().skip(i + 1) {
            if a.intersects(b) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::{Chunk, DType, TensorTable};
    use crate::schedule::{CommOp, Dep, TransferKind};

    fn base() -> (CommSchedule, Chunk) {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let c = Chunk::new(x, Region::rows(0, 4, 16));
        (CommSchedule::new(2, t), c)
    }

    fn push(peer: usize, c: &Chunk, deps: Vec<Dep>) -> CommOp {
        CommOp::P2p {
            kind: TransferKind::Push,
            peer,
            src: c.clone(),
            dst: c.clone(),
            reduce: false,
            deps,
        }
    }

    #[test]
    fn empty_schedule_valid() {
        let (s, _) = base();
        validate(&s).unwrap();
        assert!(topo_order(&s).unwrap().is_empty());
    }

    #[test]
    fn valid_simple_exchange() {
        let (mut s, c) = base();
        s.add_op(0, push(1, &c, vec![])).unwrap();
        s.add_op(1, push(0, &c, vec![Dep::on(0, 0)])).unwrap();
        validate(&s).unwrap();
        let order = topo_order(&s).unwrap();
        assert_eq!(order.len(), 2);
        assert_eq!(order[0], OpRef { rank: 0, index: 0 });
    }

    #[test]
    fn self_p2p_rejected() {
        let (mut s, c) = base();
        s.add_op(0, push(0, &c, vec![])).unwrap();
        let e = validate(&s).unwrap_err();
        assert!(e.to_string().contains("self"));
    }

    #[test]
    fn peer_oob_rejected() {
        let (mut s, c) = base();
        s.add_op(0, push(7, &c, vec![])).unwrap();
        assert!(validate(&s).is_err());
    }

    #[test]
    fn oversized_region_rejected() {
        let (mut s, c) = base();
        let bad = Chunk::new(c.tensor, Region::rows(6, 4, 16));
        s.add_op(0, push(1, &bad, vec![])).unwrap();
        assert!(validate(&s).is_err());
    }

    #[test]
    fn elem_mismatch_rejected() {
        let (mut s, c) = base();
        let small = Chunk::new(c.tensor, Region::rows(0, 2, 16));
        s.add_op(
            0,
            CommOp::P2p {
                kind: TransferKind::Push,
                peer: 1,
                src: c.clone(),
                dst: small,
                reduce: false,
                deps: vec![],
            },
        )
        .unwrap();
        assert!(validate(&s).is_err());
    }

    #[test]
    fn missing_dep_rejected() {
        let (mut s, c) = base();
        s.add_op(0, push(1, &c, vec![Dep::on(1, 5)])).unwrap();
        let e = validate(&s).unwrap_err();
        assert!(e.to_string().contains("missing op"), "{e}");
    }

    #[test]
    fn dep_cycle_detected() {
        let (mut s, c) = base();
        // 0/0 waits on 1/0; 1/0 waits on 0/0 -> deadlock
        s.add_op(0, push(1, &c, vec![Dep::on(1, 0)])).unwrap();
        s.add_op(1, push(0, &c, vec![Dep::on(0, 0)])).unwrap();
        let e = validate(&s).unwrap_err();
        assert!(e.to_string().contains("cycle"), "{e}");
    }

    #[test]
    fn program_order_plus_dep_cycle_detected() {
        let (mut s, c) = base();
        // rank0: op0 waits on rank1 op1. rank1: op0 free, op1 waits rank0 op0.
        // cycle: r0o0 <- r1o1 <- (prog) r1o0? no... r1o1 deps r0o0, r0o0 deps
        // r1o1 => direct cycle through deps.
        s.add_op(0, push(1, &c, vec![Dep::on(1, 1)])).unwrap();
        s.add_op(1, push(0, &c, vec![])).unwrap();
        s.add_op(1, push(0, &c, vec![Dep::on(0, 0)])).unwrap();
        assert!(validate(&s).is_err());
    }

    #[test]
    fn collective_group_checks() {
        let (mut s, c) = base();
        s.add_op(
            0,
            CommOp::Collective {
                kind: crate::schedule::CollectiveKind::AllGather,
                src: c.clone(),
                dst: c.clone(),
                ranks: vec![0, 0],
                deps: vec![],
            },
        )
        .unwrap();
        assert!(validate(&s).unwrap_err().to_string().contains("duplicate"));

        let (mut s2, c2) = base();
        s2.add_op(
            1,
            CommOp::Collective {
                kind: crate::schedule::CollectiveKind::AllGather,
                src: c2.clone(),
                dst: c2.clone(),
                ranks: vec![0],
                deps: vec![],
            },
        )
        .unwrap();
        assert!(validate(&s2).unwrap_err().to_string().contains("not in collective"));
    }

    #[test]
    fn topo_order_respects_deps() {
        let (mut s, c) = base();
        s.add_op(0, push(1, &c, vec![])).unwrap(); // (0,0)
        s.add_op(0, push(1, &c, vec![])).unwrap(); // (0,1) after (0,0) prog
        s.add_op(1, push(0, &c, vec![Dep::on(0, 1)])).unwrap(); // (1,0)
        let order = topo_order(&s).unwrap();
        let pos = |r: usize, i: usize| {
            order.iter().position(|o| *o == OpRef { rank: r, index: i }).unwrap()
        };
        assert!(pos(0, 0) < pos(0, 1));
        assert!(pos(0, 1) < pos(1, 0));
    }

    // -- write-hazard (overlap/duplicate-region) checks ---------------------

    #[test]
    fn unordered_duplicate_writes_rejected() {
        // two owners push the SAME region into rank 2 with no dependency path
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let c = Chunk::new(x, Region::rows(0, 4, 16));
        let mut s = CommSchedule::new(3, t);
        s.add_op(0, push(2, &c, vec![])).unwrap();
        s.add_op(1, push(2, &c, vec![])).unwrap();
        let e = validate(&s).unwrap_err();
        assert!(e.to_string().contains("unordered overlapping writes"), "{e}");
    }

    #[test]
    fn ordered_duplicate_writes_accepted() {
        // same two writes, but the second depends on the first: determinate.
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let c = Chunk::new(x, Region::rows(0, 4, 16));
        let mut s = CommSchedule::new(3, t);
        s.add_op(0, push(2, &c, vec![])).unwrap();
        s.add_op(1, push(2, &c, vec![Dep::on(0, 0)])).unwrap();
        validate(&s).unwrap();
    }

    #[test]
    fn reduce_writes_may_overlap_unordered() {
        // commutative accumulation: plan_prep serializes these at exec time
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let c = Chunk::new(x, Region::rows(0, 4, 16));
        let r = |peer: usize| CommOp::P2p {
            kind: TransferKind::Push,
            peer,
            src: c.clone(),
            dst: c.clone(),
            reduce: true,
            deps: vec![],
        };
        let mut s = CommSchedule::new(3, t);
        s.add_op(0, r(2)).unwrap();
        s.add_op(1, r(2)).unwrap();
        validate(&s).unwrap();
        // ...but a plain write racing a reduce write is still rejected
        let mut bad = s.clone();
        bad.add_op(1, push(2, &c, vec![])).unwrap();
        assert!(validate(&bad).is_err());
    }

    #[test]
    fn unordered_partial_overlap_rejected() {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let a = Chunk::new(x, Region::rows(0, 4, 16));
        let b = Chunk::new(x, Region::rows(2, 4, 16));
        let mut s = CommSchedule::new(3, t);
        s.add_op(0, push(2, &a, vec![])).unwrap();
        s.add_op(1, push(2, &b, vec![])).unwrap();
        let e = validate(&s).unwrap_err();
        assert!(e.to_string().contains("race"), "{e}");
    }

    #[test]
    fn unordered_read_write_rejected() {
        // rank 0 overwrites x[0:4] on rank 1 while rank 1's own push still
        // sources it — whether rank 1 sends pre- or post-write bytes is a
        // timing accident. validate historically missed this (only
        // write-write was checked).
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let lo = Chunk::new(x, Region::rows(0, 4, 16));
        let hi = Chunk::new(x, Region::rows(4, 4, 16));
        let mut s = CommSchedule::new(2, t);
        s.add_op(0, push(1, &lo, vec![])).unwrap();
        s.add_op(
            1,
            CommOp::P2p {
                kind: TransferKind::Push,
                peer: 0,
                src: lo.clone(),
                dst: hi.clone(),
                reduce: false,
                deps: vec![],
            },
        )
        .unwrap();
        let e = validate(&s).unwrap_err();
        assert!(e.to_string().contains("read-write"), "{e}");
        assert!(e.to_string().contains("race"), "{e}");
    }

    #[test]
    fn ordered_read_write_accepted() {
        // same shape of plan, but the reader waits for the write: determinate
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let lo = Chunk::new(x, Region::rows(0, 4, 16));
        let hi = Chunk::new(x, Region::rows(4, 4, 16));
        let mut s = CommSchedule::new(2, t);
        s.add_op(0, push(1, &lo, vec![])).unwrap();
        s.add_op(
            1,
            CommOp::P2p {
                kind: TransferKind::Push,
                peer: 0,
                src: lo.clone(),
                dst: hi.clone(),
                reduce: false,
                deps: vec![Dep::on(0, 0)],
            },
        )
        .unwrap();
        validate(&s).unwrap();
    }

    #[test]
    fn cycle_error_carries_certificate_path() {
        let (mut s, c) = base();
        s.add_op(0, push(1, &c, vec![Dep::on(1, 0)])).unwrap();
        s.add_op(1, push(0, &c, vec![Dep::on(0, 0)])).unwrap();
        let e = topo_order(&s).unwrap_err().to_string();
        assert!(e.contains("cycle"), "{e}");
        assert!(e.contains("(0,0)") && e.contains("(1,0)"), "{e}");
    }

    // -- gather-destination coverage (check_covers wired into validate) -----

    #[test]
    fn gather_destination_exact_tiling_accepted() {
        // rank 0 sends both halves: rank 1 assembles the full tensor as an
        // exact tiling; rank 0's sourced-but-never-received regions tile too.
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let lo = Chunk::new(x, Region::rows(0, 4, 16));
        let hi = Chunk::new(x, Region::rows(4, 4, 16));
        let mut s = CommSchedule::new(2, t);
        s.add_op(0, push(1, &lo, vec![])).unwrap();
        s.add_op(0, push(1, &hi, vec![])).unwrap();
        validate(&s).unwrap();
    }

    #[test]
    fn gather_destination_overlapping_tiling_rejected() {
        // classic off-by-row gather bug: regions sum to the tensor size but
        // overlap (and therefore leave a gap). Program order on rank 0 makes
        // the writes race-free, so only the coverage check can catch it.
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let a = Chunk::new(x, Region::rows(0, 4, 16));
        let b = Chunk::new(x, Region::rows(2, 4, 16));
        let mut s = CommSchedule::new(2, t);
        s.add_op(0, push(1, &a, vec![])).unwrap();
        s.add_op(0, push(1, &b, vec![])).unwrap();
        let e = validate(&s).unwrap_err();
        assert!(e.to_string().contains("exact tiling"), "{e}");
    }

    #[test]
    fn partial_transfers_skip_coverage() {
        // a plan that moves only half the tensor is not a gather and passes
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let lo = Chunk::new(x, Region::rows(0, 4, 16));
        let mut s = CommSchedule::new(2, t);
        s.add_op(0, push(1, &lo, vec![])).unwrap();
        validate(&s).unwrap();
    }

    #[test]
    fn every_template_passes_strict_validate() {
        // the strengthened validate() must keep admitting all templates
        use crate::schedule::templates as tp;
        for world in [2usize, 4] {
            let mut t = TensorTable::new();
            let x = t.declare("x", &[world * world * 2, 16], DType::F32).unwrap();
            for s in [
                tp::all_gather_ring(&t, x, 0, world).unwrap(),
                tp::all_gather_swizzle(&t, x, 0, world).unwrap(),
                tp::all_gather_direct(&t, x, 0, world).unwrap(),
                tp::reduce_scatter_ring(&t, x, 0, world).unwrap(),
                tp::reduce_scatter_direct(&t, x, 0, world).unwrap(),
                tp::all_reduce_partition(&t, x, 0, world).unwrap(),
                tp::all_reduce_rs_ag(&t, x, 0, world).unwrap(),
                tp::all_to_all(&t, x, 0, world).unwrap(),
            ] {
                validate(&s).unwrap();
                validate(&s.split_p2p(0, 2).unwrap()).unwrap();
            }
        }
    }

    #[test]
    fn covers_exact_tiling() {
        let shape = [8, 16];
        let rs: Vec<Region> = (0..4).map(|i| Region::rows(i * 2, 2, 16)).collect();
        assert!(check_covers(&shape, &rs));
        // overlap
        let mut bad = rs.clone();
        bad[1] = Region::rows(1, 2, 16);
        assert!(!check_covers(&shape, &bad));
        // missing coverage
        assert!(!check_covers(&shape, &rs[..3]));
        // out of bounds
        let oob = vec![Region::rows(0, 9, 16)];
        assert!(!check_covers(&shape, &oob));
    }
}
