//! Structural validation of communication schedules.
//!
//! A schedule admitted here is guaranteed to be *executable*: every op's
//! chunks fit their tensors, every dependency resolves to an existing op,
//! peers are in range, and the global happens-before relation (per-rank
//! program order ∪ cross-rank deps) is acyclic, i.e. deadlock-free.

use std::collections::HashSet;

use crate::chunk::Region;
use crate::error::{Error, Result};
use crate::schedule::{CommOp, CommSchedule, OpRef};

/// Validate a schedule; returns `Ok(())` or the first violation found.
pub fn validate(sched: &CommSchedule) -> Result<()> {
    if sched.per_rank.len() != sched.world {
        return Err(Error::Schedule(format!(
            "per_rank has {} entries for world {}",
            sched.per_rank.len(),
            sched.world
        )));
    }
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        for (index, op) in ops.iter().enumerate() {
            let at = format!("op ({rank},{index})");
            // chunk bounds
            op.consumed_chunk()
                .validate(&sched.tensors)
                .map_err(|e| Error::Schedule(format!("{at}: src {e}")))?;
            op.produced_chunk()
                .validate(&sched.tensors)
                .map_err(|e| Error::Schedule(format!("{at}: dst {e}")))?;
            // element-count match between src and dst
            if op.consumed_chunk().region.elems() != op.produced_chunk().region.elems() {
                return Err(Error::Schedule(format!(
                    "{at}: src/dst element counts differ ({} vs {})",
                    op.consumed_chunk().region.elems(),
                    op.produced_chunk().region.elems()
                )));
            }
            // peer / group ranks in range
            match op {
                CommOp::P2p { peer, .. } => {
                    if *peer >= sched.world {
                        return Err(Error::Schedule(format!("{at}: peer {peer} oob")));
                    }
                    if *peer == rank {
                        return Err(Error::Schedule(format!(
                            "{at}: P2P with self (use LocalCopy)"
                        )));
                    }
                }
                CommOp::Collective { ranks, .. } => {
                    let set: HashSet<_> = ranks.iter().collect();
                    if set.len() != ranks.len() {
                        return Err(Error::Schedule(format!("{at}: duplicate group ranks")));
                    }
                    if ranks.iter().any(|&r| r >= sched.world) {
                        return Err(Error::Schedule(format!("{at}: group rank oob")));
                    }
                    if !ranks.contains(&rank) {
                        return Err(Error::Schedule(format!(
                            "{at}: issuing rank not in collective group"
                        )));
                    }
                }
                CommOp::LocalCopy { .. } => {}
            }
            // dep resolvability
            for d in op.deps() {
                if d.rank >= sched.world {
                    return Err(Error::Schedule(format!("{at}: dep rank {} oob", d.rank)));
                }
                if d.index >= sched.per_rank[d.rank].len() {
                    return Err(Error::Schedule(format!(
                        "{at}: dep ({}, {}) references missing op",
                        d.rank, d.index
                    )));
                }
            }
        }
    }
    check_acyclic(sched)
}

/// Deadlock-freedom: the relation {program order on each rank} ∪ {dep edges}
/// must be a DAG. Returns a topological order of all ops when acyclic.
pub fn topo_order(sched: &CommSchedule) -> Result<Vec<OpRef>> {
    // Node numbering: prefix sums of per-rank op counts.
    let mut base = vec![0usize; sched.world + 1];
    for r in 0..sched.world {
        base[r + 1] = base[r] + sched.per_rank[r].len();
    }
    let n = base[sched.world];
    let id = |op: OpRef| base[op.rank] + op.index;

    let mut indeg = vec![0usize; n];
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        for (index, op) in ops.iter().enumerate() {
            let me = id(OpRef { rank, index });
            if index > 0 {
                // program order: ops on a rank *issue* in list order
                adj[me - 1].push(me);
                indeg[me] += 1;
            }
            for d in op.deps() {
                let dep = id(OpRef { rank: d.rank, index: d.index });
                adj[dep].push(me);
                indeg[me] += 1;
            }
        }
    }
    let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(u) = queue.pop() {
        order.push(u);
        for &v in &adj[u] {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                queue.push(v);
            }
        }
    }
    if order.len() != n {
        return Err(Error::Schedule(format!(
            "dependency cycle: only {}/{} ops orderable (deadlock)",
            order.len(),
            n
        )));
    }
    // map back to OpRefs
    let mut refs = Vec::with_capacity(n);
    for u in order {
        let rank = (0..sched.world).find(|&r| base[r] <= u && u < base[r + 1]).unwrap();
        refs.push(OpRef { rank, index: u - base[rank] });
    }
    Ok(refs)
}

fn check_acyclic(sched: &CommSchedule) -> Result<()> {
    topo_order(sched).map(|_| ())
}

/// Do `regions` tile `shape` exactly — full coverage, no overlap?
///
/// Used to check that collective templates account for every element
/// (an AllGather whose shards miss a row is silently wrong otherwise).
pub fn check_covers(shape: &[usize], regions: &[Region]) -> bool {
    let total: usize = shape.iter().product();
    let sum: usize = regions.iter().map(|r| r.elems()).sum();
    if sum != total {
        return false;
    }
    for r in regions {
        if !r.fits(shape) {
            return false;
        }
    }
    for (i, a) in regions.iter().enumerate() {
        for b in regions.iter().skip(i + 1) {
            if a.intersects(b) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::{Chunk, DType, TensorTable};
    use crate::schedule::{CommOp, Dep, TransferKind};

    fn base() -> (CommSchedule, Chunk) {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let c = Chunk::new(x, Region::rows(0, 4, 16));
        (CommSchedule::new(2, t), c)
    }

    fn push(peer: usize, c: &Chunk, deps: Vec<Dep>) -> CommOp {
        CommOp::P2p {
            kind: TransferKind::Push,
            peer,
            src: c.clone(),
            dst: c.clone(),
            reduce: false,
            deps,
        }
    }

    #[test]
    fn empty_schedule_valid() {
        let (s, _) = base();
        validate(&s).unwrap();
        assert!(topo_order(&s).unwrap().is_empty());
    }

    #[test]
    fn valid_simple_exchange() {
        let (mut s, c) = base();
        s.add_op(0, push(1, &c, vec![])).unwrap();
        s.add_op(1, push(0, &c, vec![Dep::on(0, 0)])).unwrap();
        validate(&s).unwrap();
        let order = topo_order(&s).unwrap();
        assert_eq!(order.len(), 2);
        assert_eq!(order[0], OpRef { rank: 0, index: 0 });
    }

    #[test]
    fn self_p2p_rejected() {
        let (mut s, c) = base();
        s.add_op(0, push(0, &c, vec![])).unwrap();
        let e = validate(&s).unwrap_err();
        assert!(e.to_string().contains("self"));
    }

    #[test]
    fn peer_oob_rejected() {
        let (mut s, c) = base();
        s.add_op(0, push(7, &c, vec![])).unwrap();
        assert!(validate(&s).is_err());
    }

    #[test]
    fn oversized_region_rejected() {
        let (mut s, c) = base();
        let bad = Chunk::new(c.tensor, Region::rows(6, 4, 16));
        s.add_op(0, push(1, &bad, vec![])).unwrap();
        assert!(validate(&s).is_err());
    }

    #[test]
    fn elem_mismatch_rejected() {
        let (mut s, c) = base();
        let small = Chunk::new(c.tensor, Region::rows(0, 2, 16));
        s.add_op(
            0,
            CommOp::P2p {
                kind: TransferKind::Push,
                peer: 1,
                src: c.clone(),
                dst: small,
                reduce: false,
                deps: vec![],
            },
        )
        .unwrap();
        assert!(validate(&s).is_err());
    }

    #[test]
    fn missing_dep_rejected() {
        let (mut s, c) = base();
        s.add_op(0, push(1, &c, vec![Dep::on(1, 5)])).unwrap();
        let e = validate(&s).unwrap_err();
        assert!(e.to_string().contains("missing op"), "{e}");
    }

    #[test]
    fn dep_cycle_detected() {
        let (mut s, c) = base();
        // 0/0 waits on 1/0; 1/0 waits on 0/0 -> deadlock
        s.add_op(0, push(1, &c, vec![Dep::on(1, 0)])).unwrap();
        s.add_op(1, push(0, &c, vec![Dep::on(0, 0)])).unwrap();
        let e = validate(&s).unwrap_err();
        assert!(e.to_string().contains("cycle"), "{e}");
    }

    #[test]
    fn program_order_plus_dep_cycle_detected() {
        let (mut s, c) = base();
        // rank0: op0 waits on rank1 op1. rank1: op0 free, op1 waits rank0 op0.
        // cycle: r0o0 <- r1o1 <- (prog) r1o0? no... r1o1 deps r0o0, r0o0 deps
        // r1o1 => direct cycle through deps.
        s.add_op(0, push(1, &c, vec![Dep::on(1, 1)])).unwrap();
        s.add_op(1, push(0, &c, vec![])).unwrap();
        s.add_op(1, push(0, &c, vec![Dep::on(0, 0)])).unwrap();
        assert!(validate(&s).is_err());
    }

    #[test]
    fn collective_group_checks() {
        let (mut s, c) = base();
        s.add_op(
            0,
            CommOp::Collective {
                kind: crate::schedule::CollectiveKind::AllGather,
                src: c.clone(),
                dst: c.clone(),
                ranks: vec![0, 0],
                deps: vec![],
            },
        )
        .unwrap();
        assert!(validate(&s).unwrap_err().to_string().contains("duplicate"));

        let (mut s2, c2) = base();
        s2.add_op(
            1,
            CommOp::Collective {
                kind: crate::schedule::CollectiveKind::AllGather,
                src: c2.clone(),
                dst: c2.clone(),
                ranks: vec![0],
                deps: vec![],
            },
        )
        .unwrap();
        assert!(validate(&s2).unwrap_err().to_string().contains("not in collective"));
    }

    #[test]
    fn topo_order_respects_deps() {
        let (mut s, c) = base();
        s.add_op(0, push(1, &c, vec![])).unwrap(); // (0,0)
        s.add_op(0, push(1, &c, vec![])).unwrap(); // (0,1) after (0,0) prog
        s.add_op(1, push(0, &c, vec![Dep::on(0, 1)])).unwrap(); // (1,0)
        let order = topo_order(&s).unwrap();
        let pos = |r: usize, i: usize| {
            order.iter().position(|o| *o == OpRef { rank: r, index: i }).unwrap()
        };
        assert!(pos(0, 0) < pos(0, 1));
        assert!(pos(0, 1) < pos(1, 0));
    }

    #[test]
    fn covers_exact_tiling() {
        let shape = [8, 16];
        let rs: Vec<Region> = (0..4).map(|i| Region::rows(i * 2, 2, 16)).collect();
        assert!(check_covers(&shape, &rs));
        // overlap
        let mut bad = rs.clone();
        bad[1] = Region::rows(1, 2, 16);
        assert!(!check_covers(&shape, &bad));
        // missing coverage
        assert!(!check_covers(&shape, &rs[..3]));
        // out of bounds
        let oob = vec![Region::rows(0, 9, 16)];
        assert!(!check_covers(&shape, &oob));
    }
}
