//! Communication schedules over chunks (paper §5.1).
//!
//! A schedule is `[rank, operations: List<CommOp>]: List` — per-rank ordered
//! lists of chunk-level operators with explicit `(rank, index)` dependencies.
//! There is no restriction that ranks perform the same ops: heterogeneous
//! patterns (Fig. 4e) are first-class.
//!
//! One generalization over the paper's Listing-2 API: the `dependency` field
//! is a *list* of `(rank, index)` tuples rather than a single tuple. Ring
//! patterns need only one; partition-based AllReduce (Fig. 4d) needs the
//! owner's re-broadcast to wait on all w-1 incoming partials, which a single
//! tuple cannot express without artificial chaining.
//!
//! Submodules:
//! * [`templates`] — reusable plans: ring/swizzle AllGather, ReduceScatter,
//!   partition AllReduce, AllToAll, hierarchical swizzles.
//! * [`validate`] — structural validation: bounds, dep resolvability,
//!   deadlock-freedom (global acyclicity), coverage helpers.

pub mod templates;
pub mod validate;


use crate::chunk::{Chunk, TensorTable};
use crate::error::{Error, Result};
use crate::topo::Rank;

/// Dependency on another rank's operation: `(rank, index)` per the paper —
/// "the current operation cannot start until the specified operation on the
/// given rank has completed".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Dep {
    pub rank: Rank,
    pub index: usize,
}

impl Dep {
    pub fn on(rank: Rank, index: usize) -> Self {
        Dep { rank, index }
    }
}

/// Which side defines a P2P transfer (paper: "If the P2P operation is defined
/// on the source side, it represents a push operation; otherwise a pull").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TransferKind {
    Push,
    Pull,
}

/// Collective operator classes the schedule can request directly; when kept
/// "direct" the lowering maps them onto optimized backend collectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    AllGather,
    ReduceScatter,
    AllReduce,
    AllToAll,
    Broadcast,
}

/// One chunk-level communication operation on a rank's list.
#[derive(Debug, Clone, PartialEq)]
pub enum CommOp {
    /// Point-to-point chunk transfer. Defined on ONE side only (see
    /// [`TransferKind`]): for `Push`, this op lives on the source rank and
    /// `peer` is the destination; for `Pull` it lives on the destination and
    /// `peer` is the source.
    P2p {
        kind: TransferKind,
        peer: Rank,
        /// Chunk read on the source rank's buffer.
        src: Chunk,
        /// Chunk written on the destination rank's buffer.
        dst: Chunk,
        /// If true, the transfer accumulates into the destination region
        /// (the in-network / fibre reduction of Fig. 4d) instead of
        /// overwriting it.
        reduce: bool,
        deps: Vec<Dep>,
    },
    /// Collective over a rank group, kept abstract until lowering.
    Collective {
        kind: CollectiveKind,
        src: Chunk,
        dst: Chunk,
        ranks: Vec<Rank>,
        deps: Vec<Dep>,
    },
    /// Rank-local region copy (layout staging).
    LocalCopy { src: Chunk, dst: Chunk, deps: Vec<Dep> },
}

impl CommOp {
    pub fn deps(&self) -> &[Dep] {
        match self {
            CommOp::P2p { deps, .. }
            | CommOp::Collective { deps, .. }
            | CommOp::LocalCopy { deps, .. } => deps,
        }
    }

    /// The chunk written at the *destination* of this op (what consumers of
    /// the op wait for).
    pub fn produced_chunk(&self) -> &Chunk {
        match self {
            CommOp::P2p { dst, .. }
            | CommOp::Collective { dst, .. }
            | CommOp::LocalCopy { dst, .. } => dst,
        }
    }

    /// The chunk read at the source.
    pub fn consumed_chunk(&self) -> &Chunk {
        match self {
            CommOp::P2p { src, .. }
            | CommOp::Collective { src, .. }
            | CommOp::LocalCopy { src, .. } => src,
        }
    }

    /// Is this a reduction-carrying op (needs a reduce-capable backend)?
    pub fn reduces(&self) -> bool {
        match self {
            CommOp::P2p { reduce, .. } => *reduce,
            CommOp::Collective { kind, .. } => matches!(
                kind,
                CollectiveKind::ReduceScatter | CollectiveKind::AllReduce
            ),
            CommOp::LocalCopy { .. } => false,
        }
    }

    /// The rank whose buffer receives data, given the rank owning this op.
    pub fn dst_rank(&self, owner: Rank) -> Rank {
        match self {
            CommOp::P2p { kind: TransferKind::Push, peer, .. } => *peer,
            CommOp::P2p { kind: TransferKind::Pull, .. } => owner,
            _ => owner,
        }
    }

    /// The rank whose buffer sources the data, given the rank owning this op.
    pub fn src_rank(&self, owner: Rank) -> Rank {
        match self {
            CommOp::P2p { kind: TransferKind::Push, .. } => owner,
            CommOp::P2p { kind: TransferKind::Pull, peer, .. } => *peer,
            _ => owner,
        }
    }
}

/// Reference to an op in a schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpRef {
    pub rank: Rank,
    pub index: usize,
}

/// A complete chunk-level communication schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct CommSchedule {
    pub world: usize,
    pub tensors: TensorTable,
    pub per_rank: Vec<Vec<CommOp>>,
}

impl CommSchedule {
    pub fn new(world: usize, tensors: TensorTable) -> Self {
        CommSchedule { world, tensors, per_rank: vec![Vec::new(); world] }
    }

    /// Append an op to `rank`'s list; returns its index.
    pub fn add_op(&mut self, rank: Rank, op: CommOp) -> Result<usize> {
        if rank >= self.world {
            return Err(Error::Schedule(format!(
                "rank {rank} out of world {}",
                self.world
            )));
        }
        self.per_rank[rank].push(op);
        Ok(self.per_rank[rank].len() - 1)
    }

    pub fn op(&self, r: OpRef) -> Result<&CommOp> {
        self.per_rank
            .get(r.rank)
            .and_then(|ops| ops.get(r.index))
            .ok_or_else(|| Error::Schedule(format!("no op at {r:?}")))
    }

    /// All op references in (rank, index) order.
    pub fn op_refs(&self) -> Vec<OpRef> {
        let mut v = Vec::new();
        for (rank, ops) in self.per_rank.iter().enumerate() {
            for index in 0..ops.len() {
                v.push(OpRef { rank, index });
            }
        }
        v
    }

    /// Total number of ops across all ranks.
    pub fn num_ops(&self) -> usize {
        self.per_rank.iter().map(|v| v.len()).sum()
    }

    /// Total bytes moved across *links* (excludes rank-local copies).
    pub fn total_link_bytes(&self) -> Result<usize> {
        let mut total = 0usize;
        for ops in &self.per_rank {
            for op in ops {
                match op {
                    CommOp::P2p { dst, .. } => total += dst.bytes(&self.tensors)?,
                    CommOp::Collective { kind, src, dst, ranks, .. } => {
                        // Standard cost model: ring AG/RS move (n-1)/n of the
                        // gathered size; AR moves 2x that; A2A moves (n-1)/n.
                        let n = ranks.len().max(1);
                        let moved = match kind {
                            CollectiveKind::AllGather | CollectiveKind::Broadcast => {
                                dst.bytes(&self.tensors)? * (n - 1) / n
                            }
                            CollectiveKind::ReduceScatter | CollectiveKind::AllToAll => {
                                src.bytes(&self.tensors)? * (n - 1) / n
                            }
                            CollectiveKind::AllReduce => {
                                2 * src.bytes(&self.tensors)? * (n - 1) / n
                            }
                        };
                        total += moved;
                    }
                    CommOp::LocalCopy { .. } => {}
                }
            }
        }
        Ok(total)
    }

    /// Append another schedule's ops after this one's (program order), with
    /// the appended ops' dep indices shifted past the existing per-rank
    /// lists. Both schedules must share the same tensor table and world —
    /// used to sequence multi-tensor plans (e.g. K and V rings).
    pub fn append(&mut self, other: &CommSchedule) -> Result<()> {
        if other.world != self.world {
            return Err(Error::Schedule("append: world mismatch".into()));
        }
        if other.tensors != self.tensors {
            return Err(Error::Schedule("append: tensor tables differ".into()));
        }
        let offsets: Vec<usize> = (0..self.world).map(|r| self.per_rank[r].len()).collect();
        for (rank, ops) in other.per_rank.iter().enumerate() {
            for op in ops {
                let mut op = op.clone();
                let deps = match &mut op {
                    CommOp::P2p { deps, .. }
                    | CommOp::Collective { deps, .. }
                    | CommOp::LocalCopy { deps, .. } => deps,
                };
                for d in deps.iter_mut() {
                    d.index += offsets[d.rank];
                }
                self.per_rank[rank].push(op);
            }
        }
        Ok(())
    }

    /// Refine the schedule by splitting every P2P op's chunks `n`-ways along
    /// `axis` — the **split factor** knob of the autotuner (§5.3). Deps are
    /// remapped so that sub-op k depends on the dep op's sub-op k (pipelined),
    /// preserving the original op's semantics.
    pub fn split_p2p(&self, axis: usize, n: usize) -> Result<CommSchedule> {
        if n == 0 {
            return Err(Error::Schedule("split factor must be >= 1".into()));
        }
        if n == 1 {
            return Ok(self.clone());
        }
        // Precompute the index map: old (rank, index) -> new base index.
        // Every P2P op expands to n ops; others stay single.
        let mut base: Vec<Vec<usize>> = Vec::with_capacity(self.world);
        for ops in &self.per_rank {
            let mut cur = 0usize;
            let mut row = Vec::with_capacity(ops.len());
            for op in ops {
                row.push(cur);
                cur += match op {
                    CommOp::P2p { .. } => n,
                    _ => 1,
                };
            }
            base.push(row);
        }
        let remap = |deps: &[Dep], k: usize| -> Result<Vec<Dep>> {
            deps.iter()
                .map(|d| {
                    let row = base
                        .get(d.rank)
                        .ok_or_else(|| Error::Schedule(format!("dep rank {} oob", d.rank)))?;
                    let b = *row
                        .get(d.index)
                        .ok_or_else(|| Error::Schedule(format!("dep index {} oob", d.index)))?;
                    // If the dep target was split, depend on its k-th sub-op;
                    // otherwise on the single lowered op.
                    let was_p2p =
                        matches!(self.per_rank[d.rank][d.index], CommOp::P2p { .. });
                    Ok(Dep { rank: d.rank, index: if was_p2p { b + k } else { b } })
                })
                .collect()
        };

        let mut out = CommSchedule::new(self.world, self.tensors.clone());
        for (rank, ops) in self.per_rank.iter().enumerate() {
            for op in ops {
                match op {
                    CommOp::P2p { kind, peer, src, dst, reduce, deps } => {
                        let srcs = src.region.split(axis, n)?;
                        let dsts = dst.region.split(axis, n)?;
                        for (k, (s, d)) in srcs.into_iter().zip(dsts).enumerate() {
                            out.add_op(
                                rank,
                                CommOp::P2p {
                                    kind: *kind,
                                    peer: *peer,
                                    src: Chunk::new(src.tensor, s),
                                    dst: Chunk::new(dst.tensor, d),
                                    reduce: *reduce,
                                    deps: remap(deps, k)?,
                                },
                            )?;
                        }
                    }
                    CommOp::Collective { kind, src, dst, ranks, deps } => {
                        out.add_op(
                            rank,
                            CommOp::Collective {
                                kind: *kind,
                                src: src.clone(),
                                dst: dst.clone(),
                                ranks: ranks.clone(),
                                deps: remap(deps, 0)?,
                            },
                        )?;
                    }
                    CommOp::LocalCopy { src, dst, deps } => {
                        out.add_op(
                            rank,
                            CommOp::LocalCopy {
                                src: src.clone(),
                                dst: dst.clone(),
                                deps: remap(deps, 0)?,
                            },
                        )?;
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::{DType, Region};

    fn mk() -> (CommSchedule, Chunk, Chunk) {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let sched = CommSchedule::new(2, t);
        let a = Chunk::new(x, Region::rows(0, 4, 16));
        let b = Chunk::new(x, Region::rows(4, 4, 16));
        (sched, a, b)
    }

    fn push(peer: Rank, src: &Chunk, dst: &Chunk, deps: Vec<Dep>) -> CommOp {
        CommOp::P2p {
            kind: TransferKind::Push,
            peer,
            src: src.clone(),
            dst: dst.clone(),
            reduce: false,
            deps,
        }
    }

    #[test]
    fn add_and_lookup_op() {
        let (mut s, a, b) = mk();
        let i = s.add_op(0, push(1, &a, &b, vec![])).unwrap();
        assert_eq!(i, 0);
        assert_eq!(s.num_ops(), 1);
        let op = s.op(OpRef { rank: 0, index: 0 }).unwrap();
        assert_eq!(op.produced_chunk(), &b);
        assert_eq!(op.consumed_chunk(), &a);
        assert!(!op.reduces());
        assert_eq!(op.dst_rank(0), 1);
        assert_eq!(op.src_rank(0), 0);
    }

    #[test]
    fn pull_src_dst_ranks() {
        let (mut s, a, b) = mk();
        s.add_op(
            1,
            CommOp::P2p {
                kind: TransferKind::Pull,
                peer: 0,
                src: a,
                dst: b,
                reduce: false,
                deps: vec![],
            },
        )
        .unwrap();
        let op = s.op(OpRef { rank: 1, index: 0 }).unwrap();
        assert_eq!(op.src_rank(1), 0);
        assert_eq!(op.dst_rank(1), 1);
    }

    #[test]
    fn rank_out_of_world_rejected() {
        let (mut s, a, b) = mk();
        let op = CommOp::LocalCopy { src: a, dst: b, deps: vec![] };
        assert!(s.add_op(2, op).is_err());
    }

    #[test]
    fn total_link_bytes_p2p() {
        let (mut s, a, b) = mk();
        s.add_op(0, push(1, &a, &b, vec![])).unwrap();
        s.add_op(1, CommOp::LocalCopy { src: a, dst: b, deps: vec![] }).unwrap();
        // only the P2P counts: 4*16 f32
        assert_eq!(s.total_link_bytes().unwrap(), 4 * 16 * 4);
    }

    #[test]
    fn collective_bytes_model() {
        let (mut s, a, _) = mk();
        let full = Chunk::new(a.tensor, Region::full(&[8, 16]));
        s.add_op(
            0,
            CommOp::Collective {
                kind: CollectiveKind::AllReduce,
                src: full.clone(),
                dst: full.clone(),
                ranks: vec![0, 1],
                deps: vec![],
            },
        )
        .unwrap();
        // AR over 2 ranks: 2 * B * 1/2 = B
        assert_eq!(s.total_link_bytes().unwrap(), 8 * 16 * 4);
        assert!(s.op(OpRef { rank: 0, index: 0 }).unwrap().reduces());
    }

    #[test]
    fn split_p2p_expands_and_remaps_deps() {
        let (mut s, a, b) = mk();
        s.add_op(0, push(1, &a, &b, vec![])).unwrap();
        // rank 1 op depends on rank 0 op 0
        s.add_op(1, push(0, &b, &a, vec![Dep::on(0, 0)])).unwrap();
        let s2 = s.split_p2p(0, 2).unwrap();
        assert_eq!(s2.per_rank[0].len(), 2);
        assert_eq!(s2.per_rank[1].len(), 2);
        // pipelined dep remap: rank1 sub-op k depends on rank0 sub-op k
        assert_eq!(s2.per_rank[1][0].deps(), &[Dep::on(0, 0)]);
        assert_eq!(s2.per_rank[1][1].deps(), &[Dep::on(0, 1)]);
        // bytes preserved
        assert_eq!(s.total_link_bytes().unwrap(), s2.total_link_bytes().unwrap());
    }

    #[test]
    fn split_factor_one_is_identity() {
        let (mut s, a, b) = mk();
        s.add_op(0, push(1, &a, &b, vec![])).unwrap();
        assert_eq!(s.split_p2p(0, 1).unwrap(), s);
        assert!(s.split_p2p(0, 0).is_err());
    }

    #[test]
    fn split_nondividing_fails() {
        let (mut s, a, b) = mk();
        s.add_op(0, push(1, &a, &b, vec![])).unwrap();
        assert!(s.split_p2p(0, 3).is_err());
    }

    #[test]
    fn op_refs_enumerates_all() {
        let (mut s, a, b) = mk();
        s.add_op(0, CommOp::LocalCopy { src: a.clone(), dst: b.clone(), deps: vec![] })
            .unwrap();
        s.add_op(1, CommOp::LocalCopy { src: a, dst: b, deps: vec![] }).unwrap();
        let refs = s.op_refs();
        assert_eq!(refs.len(), 2);
        assert_eq!(refs[0], OpRef { rank: 0, index: 0 });
        assert_eq!(refs[1], OpRef { rank: 1, index: 0 });
    }
}
