//! Reusable chunk-schedule templates (paper §4, §5.1, Fig. 4).
//!
//! Users instantiate these with chunk sizes, mesh topologies, communication
//! axes and pipeline depths; distributed compilers lower their collectives
//! onto them (`lowering::collective`, path = "template").
//!
//! Conventions shared with `exec::`:
//! * every tensor is declared at its *global* logical shape; each rank holds
//!   a full-size buffer of which only its shard is initially valid;
//! * AllGather over axis `a`: rank `r` initially owns shard `r` (the r-th of
//!   `world` equal slabs along `a`) and finishes owning the full tensor;
//! * ReduceScatter: every rank starts with a full *partial* tensor and rank
//!   `r` finishes owning the fully-reduced shard `r`;
//! * AllToAll: the tensor is a `world × world` block grid along the axis;
//!   rank `i` starts owning block row `i` and finishes owning block column
//!   `i` (blocks land at their global positions).

use crate::chunk::{Chunk, Region, TensorId, TensorTable};
use crate::error::{Error, Result};
use crate::schedule::{CommOp, CommSchedule, Dep, TransferKind};
use crate::topo::{Rank, Topology};

/// The `i`-th of `world` equal slabs of `shape` along `axis`.
pub fn shard_region(shape: &[usize], axis: usize, world: usize, i: usize) -> Result<Region> {
    if axis >= shape.len() {
        return Err(Error::Schedule(format!("axis {axis} out of rank {}", shape.len())));
    }
    if world == 0 || shape[axis] % world != 0 {
        return Err(Error::Schedule(format!(
            "dim {} on axis {axis} not divisible by world {world}",
            shape[axis]
        )));
    }
    if i >= world {
        return Err(Error::Schedule(format!("shard index {i} >= world {world}")));
    }
    let step = shape[axis] / world;
    let mut offset = vec![0; shape.len()];
    let mut sizes = shape.to_vec();
    offset[axis] = i * step;
    sizes[axis] = step;
    Ok(Region { offset, sizes })
}

fn shard_chunk(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
    i: usize,
) -> Result<Chunk> {
    let shape = table.get(tensor)?.shape.clone();
    Ok(Chunk::new(tensor, shard_region(&shape, axis, world, i)?))
}

/// Ring AllGather (Fig. 4c): at step `s`, rank `r` pushes shard
/// `(r - s) mod w` to its ring successor; step `s >= 1` depends on the
/// predecessor's step `s-1` push (which delivered that shard here).
pub fn all_gather_ring(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
) -> Result<CommSchedule> {
    let mut sched = CommSchedule::new(world, table.clone());
    for r in 0..world {
        for s in 0..world.saturating_sub(1) {
            let idx = (r + world - s) % world;
            let c = shard_chunk(table, tensor, axis, world, idx)?;
            let deps = if s == 0 {
                vec![]
            } else {
                vec![Dep::on((r + world - 1) % world, s - 1)]
            };
            sched.add_op(
                r,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: (r + 1) % world,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps,
                },
            )?;
        }
    }
    Ok(sched)
}

/// 1-D swizzled AllGather (Listing 2): rank `r` pulls the shard of peer
/// `(r + i) mod w` at step `i`. No dependencies — every shard is pulled
/// straight from its owner, and the swizzle staggers link usage so no two
/// ranks hit the same peer at the same step.
pub fn all_gather_swizzle(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
) -> Result<CommSchedule> {
    let mut sched = CommSchedule::new(world, table.clone());
    for r in 0..world {
        for i in 1..world {
            let peer = (r + i) % world;
            let c = shard_chunk(table, tensor, axis, world, peer)?;
            sched.add_op(
                r,
                CommOp::P2p {
                    kind: TransferKind::Pull,
                    peer,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps: vec![],
                },
            )?;
        }
    }
    Ok(sched)
}

/// Direct (push-based) AllGather: every rank pushes its own shard to every
/// peer. Maximum parallelism, maximum link contention — the naive plan
/// kernel-level compilers emit per partition.
pub fn all_gather_direct(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
) -> Result<CommSchedule> {
    let mut sched = CommSchedule::new(world, table.clone());
    for r in 0..world {
        let own = shard_chunk(table, tensor, axis, world, r)?;
        for i in 1..world {
            let peer = (r + i) % world;
            sched.add_op(
                r,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer,
                    src: own.clone(),
                    dst: own.clone(),
                    reduce: false,
                    deps: vec![],
                },
            )?;
        }
    }
    Ok(sched)
}

/// Ring ReduceScatter: at step `s`, rank `r` pushes-with-reduce shard
/// `(r - 1 - s) mod w` to its successor. After `w-1` steps rank `r` owns the
/// fully reduced shard `r`.
pub fn reduce_scatter_ring(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
) -> Result<CommSchedule> {
    let mut sched = CommSchedule::new(world, table.clone());
    for r in 0..world {
        for s in 0..world.saturating_sub(1) {
            let idx = (r + 2 * world - 1 - s) % world;
            let c = shard_chunk(table, tensor, axis, world, idx)?;
            let deps = if s == 0 {
                vec![]
            } else {
                vec![Dep::on((r + world - 1) % world, s - 1)]
            };
            sched.add_op(
                r,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: (r + 1) % world,
                    src: c.clone(),
                    dst: c,
                    reduce: true,
                    deps,
                },
            )?;
        }
    }
    Ok(sched)
}

/// Direct ReduceScatter: rank `r` pushes-with-reduce its partial of shard `j`
/// straight to owner `j`, for every `j != r`. Order-free (reduction is
/// commutative); shard `r`'s own partial is already in place.
pub fn reduce_scatter_direct(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
) -> Result<CommSchedule> {
    let mut sched = CommSchedule::new(world, table.clone());
    for r in 0..world {
        for j in 0..world {
            if j == r {
                continue;
            }
            let c = shard_chunk(table, tensor, axis, world, j)?;
            sched.add_op(
                r,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: j,
                    src: c.clone(),
                    dst: c,
                    reduce: true,
                    deps: vec![],
                },
            )?;
        }
    }
    Ok(sched)
}

/// Partition-based AllReduce (Fig. 4d): each rank pushes its partial of
/// shard `j` to owner `j` (reduction on the fibre), then each owner
/// re-broadcasts its reduced shard, waiting on **all** `w-1` incoming
/// partials (this is where the multi-dep generalization is required).
pub fn all_reduce_partition(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
) -> Result<CommSchedule> {
    let mut sched = reduce_scatter_direct(table, tensor, axis, world)?;
    // In rank q's op list, the push targeting rank j sits at index
    // j - (j > q): targets ascend with q's own index skipped.
    let incoming_idx = |q: Rank, target: Rank| -> usize {
        if target > q {
            target - 1
        } else {
            target
        }
    };
    for r in 0..world {
        let own = shard_chunk(table, tensor, axis, world, r)?;
        let deps: Vec<Dep> = (0..world)
            .filter(|&q| q != r)
            .map(|q| Dep::on(q, incoming_idx(q, r)))
            .collect();
        for i in 1..world {
            let peer = (r + i) % world;
            sched.add_op(
                r,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer,
                    src: own.clone(),
                    dst: own.clone(),
                    reduce: false,
                    deps: deps.clone(),
                },
            )?;
        }
    }
    Ok(sched)
}

/// AllReduce as ring ReduceScatter followed by ring AllGather, with the AG
/// phase's first push depending on the RS phase's completion of the local
/// reduced shard (delivered by the predecessor's last RS push).
pub fn all_reduce_rs_ag(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
) -> Result<CommSchedule> {
    if world < 2 {
        return Ok(CommSchedule::new(world, table.clone()));
    }
    let mut sched = reduce_scatter_ring(table, tensor, axis, world)?;
    let rs_ops = world - 1;
    for r in 0..world {
        for s in 0..world - 1 {
            let idx = (r + world - s) % world;
            let c = shard_chunk(table, tensor, axis, world, idx)?;
            let deps = if s == 0 {
                // own reduced shard landed with predecessor's last RS push
                vec![Dep::on((r + world - 1) % world, rs_ops - 1)]
            } else {
                vec![Dep::on((r + world - 1) % world, rs_ops + s - 1)]
            };
            sched.add_op(
                r,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: (r + 1) % world,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps,
                },
            )?;
        }
    }
    Ok(sched)
}

/// AllToAll over a `world x world` block grid along `axis`: rank `i` pushes
/// block `(i, j)` to rank `j`. Block `(i, j)` is the `(i*w + j)`-th of
/// `w*w` slabs.
pub fn all_to_all(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
) -> Result<CommSchedule> {
    let shape = table.get(tensor)?.shape.clone();
    let blocks = world * world;
    if shape[axis] % blocks != 0 {
        return Err(Error::Schedule(format!(
            "A2A needs axis dim {} divisible by world^2 = {blocks}",
            shape[axis]
        )));
    }
    let mut sched = CommSchedule::new(world, table.clone());
    for i in 0..world {
        for jj in 1..world {
            // swizzle target order to stagger link usage, like the AG swizzle
            let j = (i + jj) % world;
            let c = Chunk::new(tensor, shard_region(&shape, axis, blocks, i * world + j)?);
            sched.add_op(
                i,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: j,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps: vec![],
                },
            )?;
        }
    }
    Ok(sched)
}

/// Inverse AllToAll over the same `world x world` block grid: rank `j`
/// owns block *column* `j` (blocks `(i, j)` for all `i` — the state
/// [`all_to_all`] leaves behind) and pushes block `(i, j)` back to row
/// owner `i`. Composing `all_to_all` with this template round-trips every
/// block, which is exactly the MoE dispatch → combine exchange pair.
pub fn all_to_all_transpose(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
) -> Result<CommSchedule> {
    let shape = table.get(tensor)?.shape.clone();
    let blocks = world * world;
    if shape[axis] % blocks != 0 {
        return Err(Error::Schedule(format!(
            "A2A needs axis dim {} divisible by world^2 = {blocks}",
            shape[axis]
        )));
    }
    let mut sched = CommSchedule::new(world, table.clone());
    for j in 0..world {
        for ii in 1..world {
            // same link-staggering swizzle as the forward exchange
            let i = (j + ii) % world;
            let c = Chunk::new(tensor, shard_region(&shape, axis, blocks, i * world + j)?);
            sched.add_op(
                j,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: i,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps: vec![],
                },
            )?;
        }
    }
    Ok(sched)
}

/// Heterogeneous hierarchical swizzled AllGather (Fig. 4e): pipelines the
/// intra-node ring with cross-node shard exchange at per-shard granularity.
///
/// Phase A: ring AllGather of local shards within each node.
/// Phase B: each rank pushes its *own* shard to its mirror rank in every
///          other node (starts immediately — no deps).
/// Phase C: each rank forwards the remote shards it received in phase B
///          around its node ring, each hop depending on the shard's arrival
///          (phase B push or previous hop).
pub fn all_gather_hierarchical(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    topo: &Topology,
) -> Result<CommSchedule> {
    let world = topo.world;
    let rpn = topo.ranks_per_node;
    let nodes = world / rpn;
    if nodes * rpn != world {
        return Err(Error::Schedule("world not divisible by ranks_per_node".into()));
    }
    if nodes == 1 {
        return all_gather_ring(table, tensor, axis, world);
    }
    let mut sched = CommSchedule::new(world, table.clone());
    let node_of = |r: Rank| r / rpn;
    let local_next = |r: Rank| node_of(r) * rpn + (r % rpn + 1) % rpn;
    let local_prev = |r: Rank| node_of(r) * rpn + (r % rpn + rpn - 1) % rpn;

    // Phase A: intra-node ring AG of local shards (rpn-1 ops per rank).
    for r in 0..world {
        let base = node_of(r) * rpn;
        for s in 0..rpn - 1 {
            let idx = base + (r % rpn + rpn - s) % rpn;
            let c = shard_chunk(table, tensor, axis, world, idx)?;
            let deps = if s == 0 { vec![] } else { vec![Dep::on(local_prev(r), s - 1)] };
            sched.add_op(
                r,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: local_next(r),
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps,
                },
            )?;
        }
    }
    // Phase B: cross-node push of own shard to the mirror rank of each other
    // node (nodes-1 ops per rank). Op indices: (rpn-1) .. (rpn-1)+(nodes-2).
    let phase_b_base = rpn - 1;
    for r in 0..world {
        let own = shard_chunk(table, tensor, axis, world, r)?;
        for dn in 1..nodes {
            let peer = ((node_of(r) + dn) % nodes) * rpn + (r % rpn);
            sched.add_op(
                r,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer,
                    src: own.clone(),
                    dst: own.clone(),
                    reduce: false,
                    deps: vec![],
                },
            )?;
        }
    }
    // Phase C: forward each received remote shard around the local ring.
    // For remote node delta dn (1..nodes), the shard of my mirror in that
    // node hops rpn-1 times. Hop s of shard group dn at rank r depends on:
    //   s == 0: the mirror's phase-B push that delivered it here;
    //   s >  0: the local predecessor's previous hop of the same group.
    let phase_c_base = phase_b_base + (nodes - 1);
    for r in 0..world {
        for dn in 1..nodes {
            let src_node = (node_of(r) + nodes - dn) % nodes;
            for s in 0..rpn - 1 {
                // shard that arrived at local offset (r%rpn - s) steps back
                let origin_off = (r % rpn + rpn - s) % rpn;
                let shard_idx = src_node * rpn + origin_off;
                let c = shard_chunk(table, tensor, axis, world, shard_idx)?;
                let deps = if s == 0 {
                    // mirror's phase-B push toward my node: in the mirror's
                    // op list, the push to node delta d sits at phase_b_base
                    // + (d-1), where d = (my_node - src_node) mod nodes = dn.
                    vec![Dep::on(shard_idx, phase_b_base + dn - 1)]
                } else {
                    vec![Dep::on(
                        local_prev(r),
                        phase_c_base + (dn - 1) * (rpn - 1) + s - 1,
                    )]
                };
                sched.add_op(
                    r,
                    CommOp::P2p {
                        kind: TransferKind::Push,
                        peer: local_next(r),
                        src: c.clone(),
                        dst: c,
                        reduce: false,
                        deps,
                    },
                )?;
            }
        }
    }
    Ok(sched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::DType;
    use crate::schedule::validate::{check_covers, validate};

    fn table(rows: usize) -> (TensorTable, TensorId) {
        let mut t = TensorTable::new();
        let id = t.declare("x", &[rows, 16], DType::F32).unwrap();
        (t, id)
    }

    /// Replay a schedule's data movement at region granularity: per-rank set
    /// of valid shard indices, ops fire when deps are done and (for pushes)
    /// the source shard is present at the owner.
    fn replay_valid_shards(
        sched: &CommSchedule,
        axis: usize,
        nshards: usize,
        initial: impl Fn(Rank) -> Vec<usize>,
    ) -> Vec<std::collections::HashSet<usize>> {
        use std::collections::HashSet;
        let shape = {
            let (id, decl) = sched.tensors.iter().next().unwrap();
            let _ = id;
            decl.shape.clone()
        };
        let shard_of = |c: &Chunk| -> usize {
            let step = shape[axis] / nshards;
            c.region.offset[axis] / step
        };
        let mut valid: Vec<HashSet<usize>> =
            (0..sched.world).map(|r| initial(r).into_iter().collect()).collect();
        let mut done: Vec<Vec<bool>> =
            sched.per_rank.iter().map(|ops| vec![false; ops.len()]).collect();
        let mut progressed = true;
        while progressed {
            progressed = false;
            for r in 0..sched.world {
                for (i, op) in sched.per_rank[r].iter().enumerate() {
                    if done[r][i] {
                        continue;
                    }
                    if !op.deps().iter().all(|d| done[d.rank][d.index]) {
                        continue;
                    }
                    let srcr = op.src_rank(r);
                    let dstr = op.dst_rank(r);
                    let sh = shard_of(op.consumed_chunk());
                    if !valid[srcr].contains(&sh) {
                        continue; // data not yet present at source
                    }
                    valid[dstr].insert(shard_of(op.produced_chunk()));
                    done[r][i] = true;
                    progressed = true;
                }
            }
        }
        assert!(
            done.iter().all(|v| v.iter().all(|&b| b)),
            "schedule did not complete: stuck ops remain"
        );
        valid
    }

    #[test]
    fn shard_region_basics() {
        let r = shard_region(&[8, 16], 0, 4, 2).unwrap();
        assert_eq!(r, Region::rows(4, 2, 16));
        assert!(shard_region(&[8, 16], 0, 3, 0).is_err());
        assert!(shard_region(&[8, 16], 2, 2, 0).is_err());
        assert!(shard_region(&[8, 16], 0, 4, 4).is_err());
    }

    #[test]
    fn ring_ag_validates_and_gathers() {
        for world in [2, 4, 8] {
            let (t, x) = table(world * 2);
            let s = all_gather_ring(&t, x, 0, world).unwrap();
            validate(&s).unwrap();
            assert_eq!(s.num_ops(), world * (world - 1));
            let valid = replay_valid_shards(&s, 0, world, |r| vec![r]);
            for v in valid {
                assert_eq!(v.len(), world, "rank missing shards after ring AG");
            }
        }
    }

    #[test]
    fn swizzle_ag_gathers_without_deps() {
        let (t, x) = table(8);
        let s = all_gather_swizzle(&t, x, 0, 4).unwrap();
        validate(&s).unwrap();
        assert!(s.per_rank.iter().flatten().all(|o| o.deps().is_empty()));
        let valid = replay_valid_shards(&s, 0, 4, |r| vec![r]);
        for v in valid {
            assert_eq!(v.len(), 4);
        }
    }

    #[test]
    fn swizzle_staggers_peers() {
        let (t, x) = table(8);
        let s = all_gather_swizzle(&t, x, 0, 4).unwrap();
        // at step i, the set of pulled peers across ranks is a permutation
        for i in 0..3 {
            let peers: std::collections::HashSet<_> = (0..4)
                .map(|r| match &s.per_rank[r][i] {
                    CommOp::P2p { peer, .. } => *peer,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(peers.len(), 4, "step {i} collides on a peer");
        }
    }

    #[test]
    fn direct_ag_gathers() {
        let (t, x) = table(8);
        let s = all_gather_direct(&t, x, 0, 4).unwrap();
        validate(&s).unwrap();
        let valid = replay_valid_shards(&s, 0, 4, |r| vec![r]);
        for v in valid {
            assert_eq!(v.len(), 4);
        }
    }

    #[test]
    fn ring_rs_validates_and_counts_reduces() {
        for world in [2, 4, 8] {
            let (t, x) = table(world * 2);
            let s = reduce_scatter_ring(&t, x, 0, world).unwrap();
            validate(&s).unwrap();
            assert!(s.per_rank.iter().flatten().all(|o| o.reduces()));
            // each shard is pushed exactly w-1 times
            let mut counts = vec![0usize; world];
            let step = (world * 2) / world;
            for op in s.per_rank.iter().flatten() {
                counts[op.produced_chunk().region.offset[0] / step] += 1;
            }
            assert!(counts.iter().all(|&c| c == world - 1), "{counts:?}");
        }
    }

    #[test]
    fn ring_rs_final_hop_lands_at_owner() {
        // the LAST push of shard k must target rank k
        let world = 4;
        let (t, x) = table(8);
        let s = reduce_scatter_ring(&t, x, 0, world).unwrap();
        // shard k's hops in dep order: find op with no *later* op pushing k
        for k in 0..world {
            let mut last_dst = None;
            // hops are rank r step s with shard (r-1-s) == k; the final hop
            // has s = world-2... 0-indexed: s from 0..w-1; find s_max
            for r in 0..world {
                for (s, op) in s.per_rank[r].iter().enumerate() {
                    let sh = op.produced_chunk().region.offset[0] / 2;
                    if sh == k && s == world - 2 {
                        last_dst = Some(op.dst_rank(r));
                    }
                }
            }
            assert_eq!(last_dst, Some(k), "shard {k} must end at rank {k}");
        }
    }

    #[test]
    fn partition_ar_multi_deps() {
        let world = 4;
        let (t, x) = table(8);
        let s = all_reduce_partition(&t, x, 0, world).unwrap();
        validate(&s).unwrap();
        // broadcast ops carry w-1 deps each
        for r in 0..world {
            for op in &s.per_rank[r][world - 1..] {
                assert_eq!(op.deps().len(), world - 1);
                assert!(!op.reduces());
            }
        }
        // full replay: everyone ends with every shard
        let valid = replay_valid_shards(&s, 0, world, |_| (0..world).collect());
        for v in valid {
            assert_eq!(v.len(), world);
        }
    }

    #[test]
    fn ar_rs_ag_validates() {
        for world in [2, 4] {
            let (t, x) = table(world * 2);
            let s = all_reduce_rs_ag(&t, x, 0, world).unwrap();
            validate(&s).unwrap();
            assert_eq!(s.num_ops(), world * 2 * (world - 1));
        }
    }

    #[test]
    fn a2a_block_exchange() {
        let world = 4;
        let (t, x) = table(world * world * 2); // 32 rows = 16 blocks of 2
        let s = all_to_all(&t, x, 0, world).unwrap();
        validate(&s).unwrap();
        // rank i pushes w-1 blocks, all from its own block row
        for i in 0..world {
            assert_eq!(s.per_rank[i].len(), world - 1);
            for op in &s.per_rank[i] {
                let blk = op.consumed_chunk().region.offset[0] / 2;
                assert_eq!(blk / world, i, "rank {i} must send its own row blocks");
            }
        }
    }

    #[test]
    fn a2a_requires_divisibility() {
        let (t, x) = table(6);
        assert!(all_to_all(&t, x, 0, 4).is_err());
        assert!(all_to_all_transpose(&t, x, 0, 4).is_err());
    }

    #[test]
    fn a2a_transpose_is_the_inverse_exchange() {
        let world = 4;
        let (t, x) = table(world * world * 2);
        let s = all_to_all_transpose(&t, x, 0, world).unwrap();
        validate(&s).unwrap();
        // rank j pushes w-1 blocks, all from its own block COLUMN, each to
        // that block's row owner
        for j in 0..world {
            assert_eq!(s.per_rank[j].len(), world - 1);
            for op in &s.per_rank[j] {
                let blk = op.consumed_chunk().region.offset[0] / 2;
                assert_eq!(blk % world, j, "rank {j} must send its own column blocks");
                assert_eq!(op.dst_rank(j), blk / world, "block must land at its row owner");
            }
        }
        // forward then inverse touches every off-diagonal block exactly twice
        let fwd = all_to_all(&t, x, 0, world).unwrap();
        assert_eq!(fwd.num_ops(), s.num_ops());
    }

    #[test]
    fn hierarchical_ag_gathers_two_nodes() {
        let topo = crate::hw::catalog::topology_nodes("h100_multinode", 2, 8).unwrap();
        let (t, x) = table(16); // 8 shards of 2 rows
        let s = all_gather_hierarchical(&t, x, 0, &topo).unwrap();
        validate(&s).unwrap();
        let valid = replay_valid_shards(&s, 0, 8, |r| vec![r]);
        for (r, v) in valid.iter().enumerate() {
            assert_eq!(v.len(), 8, "rank {r} missing shards: {v:?}");
        }
    }

    #[test]
    fn hierarchical_ag_single_node_falls_back_to_ring() {
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let (t, x) = table(8);
        let a = all_gather_hierarchical(&t, x, 0, &topo).unwrap();
        let b = all_gather_ring(&t, x, 0, 4).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn hierarchical_ag_three_nodes() {
        let topo = crate::hw::catalog::topology_nodes("h100_multinode", 3, 6).unwrap();
        let (t, x) = table(12); // 6 shards of 2
        let s = all_gather_hierarchical(&t, x, 0, &topo).unwrap();
        validate(&s).unwrap();
        let valid = replay_valid_shards(&s, 0, 6, |r| vec![r]);
        for (r, v) in valid.iter().enumerate() {
            assert_eq!(v.len(), 6, "rank {r}: {v:?}");
        }
    }

    #[test]
    fn ag_shards_cover_tensor() {
        let (t, x) = table(8);
        let shape = t.get(x).unwrap().shape.clone();
        let regions: Vec<Region> =
            (0..4).map(|i| shard_region(&shape, 0, 4, i).unwrap()).collect();
        assert!(check_covers(&shape, &regions));
    }
}
