//! Report generators: one function per paper table/figure.
//!
//! Shared by the CLI (`syncopate report ...`) and the bench harnesses
//! (`cargo bench`), so EXPERIMENTS.md numbers regenerate from exactly one
//! code path. Each function returns a [`Table`] whose rows/series mirror
//! what the paper plots; DESIGN.md §5 maps figures to these functions.

use crate::autotune::{self, Budget};
use crate::backend::BackendKind;
use crate::baselines::{self, Baseline};
use crate::codegen::Realization;
use crate::coordinator::operators::compile_operator;
use crate::coordinator::TuneConfig;
use crate::error::Result;
use crate::kernel::scheduler::{IntraOrder, SwizzlePolicy};
use crate::lowering::collective::LowerPath;
use crate::lowering::{loops, partition};
use crate::metrics::Table;
use crate::schedule::CommSchedule;
use crate::sim::engine::{simulate, SimParams};
use crate::sim::waves;
use crate::topo::Topology;
use crate::workload::{
    OpKind, OperatorInstance, DEFAULT_TOKENS, LLAMA3_405B, LLAMA3_70B, LLAMA3_8B, MODELS,
    QWEN_72B, SEQ_SWEEP,
};

/// Table 2: communication mechanism comparison (achieved bandwidth at a
/// large message + capability flags encoded as 0/1).
pub fn table2() -> Table {
    let topo = crate::hw::catalog::topology("h100_node", 8).unwrap();
    let mut t = Table::new(
        "Table 2: GPU communication mechanisms",
        &["bw GB/s @256MiB", "bw @1MiB", "collective-reduce", "host-launched", "SM-driven"],
        "mixed",
    );
    for b in [BackendKind::CopyEngine, BackendKind::TmaSpecialized, BackendKind::LdStSpecialized] {
        let caps = topo.arch.caps(b);
        let sms = topo.arch.curve(b).sms_for_peak.max(0);
        t.push_row(
            b.name(),
            vec![
                topo.arch.effective_bandwidth_gbps(b, 256 << 20, sms, topo.intra),
                topo.arch.effective_bandwidth_gbps(b, 1 << 20, sms, topo.intra),
                caps.supports_reduce as u8 as f64,
                caps.host_launched as u8 as f64,
                (topo.arch.curve(b).sms_for_peak > 0) as u8 as f64,
            ],
        );
    }
    t
}

/// Fig. 2(a): SM utilization vs GEMM size under several tile configs.
pub fn fig2a() -> Table {
    let mut t = Table::new(
        "Fig 2a: SM utilization vs GEMM size (132 SMs)",
        &["tile 64x64", "tile 128x128", "tile 256x128"],
        "utilization",
    );
    for m in [512usize, 1024, 2048, 4096, 8192, 16384] {
        t.push_row(
            &format!("M=N={m}"),
            vec![
                waves::gemm_sm_utilization(m, m, 64, 64, 132),
                waves::gemm_sm_utilization(m, m, 128, 128, 132),
                waves::gemm_sm_utilization(m, m, 256, 128, 132),
            ],
        );
    }
    t
}

/// Fig. 2(b): streamed (persistent, fused) vs kernel-partitioned GEMM.
pub fn fig2b() -> Result<Table> {
    let topo = crate::hw::catalog::topology("h100_node", 8)?;
    let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, DEFAULT_TOKENS, 8);
    let mut t = Table::new(
        "Fig 2b: streamed kernel vs kernel-partitioned (AG-GEMM, 70B shape)",
        &["streamed", "partitioned"],
        "TFLOPS",
    );
    // identical phase schedule; toggle only the kernel structure
    for &k in &[1usize, 2, 4, 8, 16] {
        let streamed = {
            let (p, params) = baselines::phased_ag_gemm(&op, &topo, k, false)?;
            simulate(&p, &topo, params)?.tflops()
        };
        let partitioned = {
            let (p, params) = baselines::phased_ag_gemm(&op, &topo, k, true)?;
            simulate(&p, &topo, params)?.tflops()
        };
        t.push_row(&format!("phases={k}"), vec![streamed, partitioned]);
    }
    Ok(t)
}

/// Fig. 2(c): achieved bandwidth vs transfer size per backend.
pub fn fig2c() -> Table {
    let topo = crate::hw::catalog::topology("h100_node", 8).unwrap();
    let mut t = Table::new(
        "Fig 2c: bandwidth vs transfer size",
        &["copy-engine", "tma(16sm)", "ldst(32sm)"],
        "GB/s",
    );
    // achieved GB/s including launch/latency overheads: bytes / (µs · 1e3)
    let gbps = |kind: BackendKind, bytes: usize, sms: usize| {
        bytes as f64 / (topo.arch.transfer_time_us(kind, bytes, 1, sms, topo.intra) * 1e3)
    };
    for kib in [4usize, 64, 1024, 4096, 65536, 262144] {
        let bytes = kib * 1024;
        t.push_row(
            &format!("{kib} KiB"),
            vec![
                gbps(BackendKind::CopyEngine, bytes, 0),
                gbps(BackendKind::TmaSpecialized, bytes, 16),
                gbps(BackendKind::LdStSpecialized, bytes, 32),
            ],
        );
    }
    t
}

/// Fig. 2(d): achieved bandwidth vs number of communication SMs.
pub fn fig2d() -> Table {
    let topo = crate::hw::catalog::topology("h100_node", 8).unwrap();
    let bytes = 64 << 20;
    let mut t = Table::new(
        "Fig 2d: bandwidth vs #SMs (64 MiB transfers)",
        &["tma", "ldst", "copy-engine"],
        "GB/s",
    );
    for sms in [1usize, 2, 4, 8, 16, 24, 32] {
        t.push_row(
            &format!("{sms} SMs"),
            vec![
                topo.arch.effective_bandwidth_gbps(BackendKind::TmaSpecialized, bytes, sms, topo.intra),
                topo.arch.effective_bandwidth_gbps(BackendKind::LdStSpecialized, bytes, sms, topo.intra),
                topo.arch.effective_bandwidth_gbps(BackendKind::CopyEngine, bytes, 0, topo.intra),
            ],
        );
    }
    t
}

/// Systems compared in Fig. 8/9 (columns).
pub const SYSTEMS: [&str; 8] = [
    "syncopate",
    "triton+nccl",
    "kernel-level",
    "flux",
    "async-tp",
    "flashoverlap",
    "triton-dist",
    "thunderkittens",
];

fn compare_systems(op: &OperatorInstance, topo: &Topology, budget: Budget) -> Result<Vec<f64>> {
    let mut row = Vec::with_capacity(SYSTEMS.len());
    let tuned = autotune::tune(op, topo, budget)?;
    row.push(tuned.tflops);
    for b in Baseline::ALL {
        if !b.supports(op) {
            row.push(f64::NAN);
            continue;
        }
        match baselines::plan(b, op, topo) {
            Ok((p, params)) => row.push(simulate(&p, topo, params)?.tflops()),
            Err(_) => row.push(f64::NAN),
        }
    }
    Ok(row)
}

/// Fig. 8: GEMM operators across models and mesh sizes vs all baselines.
pub fn fig8(budget: Budget) -> Result<Table> {
    let mut t = Table::new("Fig 8: distributed GEMM operators", &SYSTEMS, "TFLOPS");
    for model in &MODELS {
        for &world in &[4usize, 8] {
            let topo = crate::hw::catalog::topology("h100_node", world)?;
            for kind in [OpKind::AgGemm, OpKind::GemmRs, OpKind::GemmAr] {
                let op = OperatorInstance::gemm(kind, model, DEFAULT_TOKENS, world);
                let row = compare_systems(&op, &topo, budget)?;
                t.push_row(&format!("{}-{}-{}gpu", model.name, kind.name(), world), row);
            }
        }
    }
    Ok(t)
}

/// Fig. 9: attention operators across sequence lengths vs baselines.
pub fn fig9(budget: Budget) -> Result<Table> {
    let mut t = Table::new("Fig 9: distributed attention operators", &SYSTEMS, "TFLOPS");
    for model in &[LLAMA3_8B, LLAMA3_70B] {
        for &world in &[4usize, 8] {
            let topo = crate::hw::catalog::topology("h100_node", world)?;
            for &seq in &SEQ_SWEEP[..3] {
                for kind in OpKind::ATTN_OPS {
                    let op = OperatorInstance::attention(kind, model, seq, world);
                    let row = compare_systems(&op, &topo, budget)?;
                    t.push_row(
                        &format!("{}-{}-s{}k-{}gpu", model.name, kind.name(), seq / 1024, world),
                        row,
                    );
                }
            }
        }
    }
    Ok(t)
}

/// Comm-only latency of a schedule under a realization (used by Fig. 10 to
/// compare lowering paths on equal footing).
pub fn comm_only_latency_us(
    sched: &CommSchedule,
    real: Realization,
    topo: &Topology,
) -> Result<f64> {
    let plan = crate::codegen::compile_comm_only(sched, real, topo)?;
    Ok(simulate(&plan, topo, SimParams::default())?.makespan_us)
}

/// Ported-vs-native comparison: comm-only latency of the baseline plans
/// lifted through `plan_io::import` next to the native AllGather templates,
/// on the same simulator and realization — the like-for-like scoring the
/// "ported from existing distributed compilers" path exists for.
pub fn ported() -> Result<Table> {
    use crate::chunk::{DType, TensorTable};
    use crate::plan_io::import;
    use crate::schedule::templates;

    let mut t = Table::new(
        "Ported plans vs native templates (comm-only AllGather latency)",
        &["ring", "swizzle", "direct", "flux-imported", "tdist-imported"],
        "us (lower=better)",
    );
    for world in [2usize, 4, 8] {
        let topo = crate::hw::catalog::topology("h100_node", world)?;
        let mut table = TensorTable::new();
        let x = table.declare("x", &[world * 1024, 4096], DType::BF16)?;
        let real = Realization::new(BackendKind::CopyEngine, 0);
        let lat = |s: &CommSchedule| comm_only_latency_us(s, real, &topo);
        t.push_row(
            &format!("{world}gpu"),
            vec![
                lat(&templates::all_gather_ring(&table, x, 0, world)?)?,
                lat(&templates::all_gather_swizzle(&table, x, 0, world)?)?,
                lat(&templates::all_gather_direct(&table, x, 0, world)?)?,
                lat(&import::flux_ag(&table, x, 0, world, 4)?)?,
                lat(&import::triton_dist_ag(&table, x, 0, world)?)?,
            ],
        );
    }
    Ok(t)
}

/// Fig. 10: higher-level compiler IRs lowered through Syncopate.
///
/// For each system we keep its parallelization strategy (the IR presets),
/// compare the *native* kernel-level execution against Syncopate's
/// fine-grained plan, and additionally show the three collective-lowering
/// paths on the IR's own communication schedule.
pub fn fig10(budget: Budget) -> Result<Table> {
    let world = 8usize;
    let topo = crate::hw::catalog::topology("h100_node", world)?;
    let mut t = Table::new(
        "Fig 10: integration with distributed compilers (8 GPU)",
        &["native", "+syncopate", "comm direct", "comm template", "comm synth"],
        "us (lower=better)",
    );
    // (system, operator that its strategy produces, partition-or-loop IR)
    let cases: Vec<(&str, OperatorInstance, CommSchedule, CommSchedule, CommSchedule)> = {
        let mk_part = |ir: &partition::PartitionIR| -> Result<(CommSchedule, CommSchedule, CommSchedule)> {
            Ok((
                partition::lower_partition_ir(ir, &topo, LowerPath::Direct)?,
                partition::lower_partition_ir(ir, &topo, LowerPath::Template)?,
                partition::lower_partition_ir(ir, &topo, LowerPath::Synth)?,
            ))
        };
        let domino = partition::presets::domino_ffn(world, DEFAULT_TOKENS, LLAMA3_70B.hidden, LLAMA3_70B.hidden);
        let alpa = partition::presets::alpa_ffn(world, DEFAULT_TOKENS, LLAMA3_70B.hidden, LLAMA3_70B.hidden);
        let mercury = loops::presets::mercury_ring_attention(
            world,
            SEQ_SWEEP[2],
            LLAMA3_70B.heads * LLAMA3_70B.head_dim,
        );
        let (d1, d2, d3) = mk_part(&domino)?;
        let (a1, a2, a3) = mk_part(&alpa)?;
        let m1 = loops::lower_loop_ir(&mercury, &topo)?;
        vec![
            (
                "domino-ffn",
                OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_70B, DEFAULT_TOKENS, world),
                d1,
                d2,
                d3,
            ),
            (
                "alpa-ffn",
                OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_70B, DEFAULT_TOKENS, world),
                a1,
                a2,
                a3,
            ),
            (
                "mercury-ring",
                OperatorInstance::attention(OpKind::RingAttn, &LLAMA3_70B, SEQ_SWEEP[2], world),
                m1.clone(),
                m1.clone(),
                m1,
            ),
        ]
    };
    for (name, op, direct, template, synth) in cases {
        let native = {
            let (p, params) = baselines::plan(Baseline::KernelLevel, &op, &topo)?;
            simulate(&p, &topo, params)?.makespan_us
        };
        let ours = autotune::tune(&op, &topo, budget)?.makespan_us;
        let nccl_real = Realization::new(BackendKind::NcclBulk, 20);
        t.push_row(
            name,
            vec![
                native,
                ours,
                comm_only_latency_us(&direct, nccl_real, &topo)?,
                comm_only_latency_us(&template, nccl_real, &topo)?,
                comm_only_latency_us(&synth, nccl_real, &topo)?,
            ],
        );
    }
    Ok(t)
}

/// Fig. 11(a): backend ablation for a fixed logical schedule.
pub fn fig11a() -> Result<Table> {
    let topo = crate::hw::catalog::topology("h100_node", 8)?;
    let mut t = Table::new(
        "Fig 11a: communication backend ablation",
        &["copy-engine", "tma-spec", "tma-coloc", "ldst-spec", "ldst-coloc"],
        "TFLOPS",
    );
    for (label, op) in [
        ("ag-gemm-70b", OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, DEFAULT_TOKENS, 8)),
        ("gemm-rs-70b", OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_70B, DEFAULT_TOKENS, 8)),
    ] {
        let mut row = Vec::new();
        for b in BackendKind::TUNABLE {
            let sms = if topo.arch.curve(b).sms_for_peak == 0 { 0 } else { 16 };
            let cfg = TuneConfig { real: Realization::new(b, sms), ..Default::default() };
            match compile_operator(&op, &cfg, &topo)
                .and_then(|(p, params)| simulate(&p, &topo, params))
            {
                Ok(r) => row.push(r.tflops()),
                Err(_) => row.push(f64::NAN), // infeasible (e.g. reduce on TMA)
            }
        }
        t.push_row(label, row);
    }
    Ok(t)
}

/// Fig. 11(b): chunk split-factor sensitivity (non-monotone, interior peak).
pub fn fig11b() -> Result<Table> {
    let topo = crate::hw::catalog::topology("h100_node", 8)?;
    let mut t = Table::new(
        "Fig 11b: chunk size (split factor) sensitivity",
        &["a2a-gemm-70b", "gemm-ar-70b"],
        "TFLOPS",
    );
    let ops = [
        OperatorInstance::gemm(OpKind::A2aGemm, &LLAMA3_70B, DEFAULT_TOKENS, 8),
        OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_70B, DEFAULT_TOKENS, 8),
    ];
    for &split in &[1usize, 2, 4, 8, 16, 32] {
        let mut row = Vec::new();
        for op in &ops {
            let real = if matches!(op.kind, OpKind::GemmAr | OpKind::GemmRs) {
                Realization::new(BackendKind::LdStSpecialized, 32)
            } else {
                Realization::new(BackendKind::CopyEngine, 0)
            };
            let cfg = TuneConfig { split, real, ..Default::default() };
            match compile_operator(op, &cfg, &topo)
                .and_then(|(p, params)| simulate(&p, &topo, params))
            {
                Ok(r) => row.push(r.tflops()),
                Err(_) => row.push(f64::NAN),
            }
        }
        t.push_row(&format!("split={split}"), row);
    }
    Ok(t)
}

/// Fig. 11(c): communication-SM allocation sweet spot.
pub fn fig11c() -> Result<Table> {
    let topo = crate::hw::catalog::topology("h100_node", 8)?;
    let mut t = Table::new(
        "Fig 11c: SM allocation (ldst-specialized)",
        &["gemm-ar-405b", "gemm-ar-70b"],
        "TFLOPS",
    );
    let ops = [
        OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_405B, DEFAULT_TOKENS, 8),
        OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_70B, DEFAULT_TOKENS, 8),
    ];
    for &sms in &[4usize, 8, 16, 32, 64, 96] {
        let mut row = Vec::new();
        for op in &ops {
            let cfg = TuneConfig {
                real: Realization::new(BackendKind::LdStSpecialized, sms),
                ..Default::default()
            };
            match compile_operator(op, &cfg, &topo)
                .and_then(|(p, params)| simulate(&p, &topo, params))
            {
                Ok(r) => row.push(r.tflops()),
                Err(_) => row.push(f64::NAN),
            }
        }
        t.push_row(&format!("{sms} SMs"), row);
    }
    Ok(t)
}

/// Fig. 11(d): intra-tile schedule spread for one GEMM configuration.
pub fn fig11d() -> Result<Table> {
    let topo = crate::hw::catalog::topology("h100_node", 8)?;
    let op = OperatorInstance::gemm(OpKind::AgGemm, &QWEN_72B, DEFAULT_TOKENS, 8);
    let mut t = Table::new(
        "Fig 11d: tile schedule / shape ablation (AG-GEMM Qwen-72B)",
        &["TFLOPS", "smem KiB"],
        "mixed",
    );
    let orders = [
        ("row-major", SwizzlePolicy::RowMajor),
        ("col-major", SwizzlePolicy::ColMajor),
        ("chunk", SwizzlePolicy::ChunkMajor { intra: IntraOrder::RowMajor }),
        ("chunk-snake", SwizzlePolicy::ChunkMajor { intra: IntraOrder::Snake }),
        ("chunk-group2", SwizzlePolicy::ChunkMajor { intra: IntraOrder::GroupedCols { group: 2 } }),
    ];
    for (bm, bn, bk) in [(128usize, 128usize, 128usize), (64, 256, 64), (256, 128, 64), (64, 64, 128)] {
        for (oname, sw) in &orders {
            let cfg = TuneConfig {
                swizzle: sw.clone(),
                block_m: bm,
                block_n: bn,
                block_k: bk,
                ..Default::default()
            };
            let Ok((p, params)) = compile_operator(&op, &cfg, &topo) else { continue };
            let Ok(r) = simulate(&p, &topo, params) else { continue };
            // shared-memory proxy: double-buffered A+B blocks (bf16)
            let smem = 2.0 * ((bm * bk + bk * bn) * 2) as f64 / 1024.0;
            t.push_row(&format!("{bm}x{bn}x{bk}-{oname}"), vec![r.tflops(), smem]);
        }
    }
    Ok(t)
}

/// Scalability & portability study (§6.1: "we vary the number of active
/// devices"): AG-GEMM and RingAttention across mesh sizes, including a
/// 2-node 16-GPU configuration (hierarchical template + inter-node links),
/// Syncopate vs the kernel-level baseline. Also carries the A2A-GEMM
/// supplement used by Fig. 11(b).
pub fn scalability(budget: Budget) -> Result<Table> {
    let mut t = Table::new(
        "Scalability: mesh size sweep (llama3-70b)",
        &["syncopate", "kernel-level", "speedup"],
        "TFLOPS (speedup: x)",
    );
    let meshes: Vec<(String, Topology)> = vec![
        ("2gpu".into(), crate::hw::catalog::topology("h100_node", 2)?),
        ("4gpu".into(), crate::hw::catalog::topology("h100_node", 4)?),
        ("8gpu".into(), crate::hw::catalog::topology("h100_node", 8)?),
        ("2x8gpu".into(), crate::hw::catalog::topology_nodes("h100_multinode", 2, 16)?),
    ];
    for (mname, topo) in &meshes {
        for kind in [OpKind::AgGemm, OpKind::A2aGemm, OpKind::RingAttn] {
            let op = if kind.is_gemm() {
                OperatorInstance::gemm(kind, &LLAMA3_70B, DEFAULT_TOKENS, topo.world)
            } else {
                OperatorInstance::attention(kind, &LLAMA3_70B, 16384, topo.world)
            };
            let ours = match autotune::tune(&op, topo, budget) {
                Ok(r) => r,
                Err(_) => continue, // e.g. A2A divisibility on some meshes
            };
            let base = baselines::plan(Baseline::KernelLevel, &op, topo)
                .and_then(|(p, params)| simulate(&p, topo, params))
                .map(|r| (r.tflops(), r.makespan_us))
                .unwrap_or((f64::NAN, f64::NAN));
            t.push_row(
                &format!("{}-{}", kind.name(), mname),
                vec![ours.tflops, base.0, base.1 / ours.makespan_us],
            );
        }
    }
    Ok(t)
}

/// Pipeline fusion: fused cross-operator plans vs. the barrier-at-boundary
/// baseline (DESIGN.md §12).
///
/// For each fused case (`tp-block` = AG-GEMM → GEMM-RS, `moe-a2a` = A2A
/// dispatch → expert GEMMs → A2A combine) and world size, the fused column
/// is the simulated makespan of the single barrier-free plan; the barrier
/// column is the sum of the per-stage plan makespans — each stage keeps
/// its internal overlap but a device-wide sync separates consecutive
/// operators, which is exactly how per-operator overlapped kernels compose
/// today. The speedup column is barrier/fused.
pub fn pipeline() -> Result<Table> {
    use crate::coordinator::execases;

    let mut t = Table::new(
        "Pipeline fusion: fused vs. barrier-at-boundary makespan",
        &["fused us", "barrier us", "speedup"],
        "us (speedup: x, lower fused = better)",
    );
    fn sum_makespans(plans: &[crate::codegen::ExecutablePlan], topo: &Topology) -> Result<f64> {
        let mut total = 0.0;
        for p in plans {
            total += simulate(p, topo, SimParams::default())?.makespan_us;
        }
        Ok(total)
    }
    for world in [2usize, 4, 8] {
        let topo = crate::hw::catalog::topology("h100_node", world)?;

        let fused = simulate(
            &execases::tp_block(world, 1, 42)?.plan,
            &topo,
            SimParams::default(),
        )?
        .makespan_us;
        let barrier = sum_makespans(&execases::tp_block_stage_plans(world, 1)?, &topo)?;
        t.push_row(&format!("tp-block-{world}gpu"), vec![fused, barrier, barrier / fused]);

        let fused = simulate(
            &execases::moe_a2a(world, 42)?.plan,
            &topo,
            SimParams::default(),
        )?
        .makespan_us;
        let barrier = sum_makespans(&execases::moe_a2a_stage_plans(world)?, &topo)?;
        t.push_row(&format!("moe-a2a-{world}gpu"), vec![fused, barrier, barrier / fused]);
    }
    Ok(t)
}

/// Arch sweep: every registry exec case simulated on every catalog
/// topology — the cross-machine comparison the data-driven hardware model
/// exists for. One row per exec case, one column per catalog shape, cell =
/// simulated makespan of the case's compiled plan on that machine (µs).
/// The CLI (`report arch-sweep`) additionally prints the per-case
/// fastest→slowest ranking.
pub fn arch_sweep() -> Result<Table> {
    use crate::coordinator::execases::{self, CaseParams};

    let names = crate::hw::catalog::names();
    let mut t = Table::new(
        "Arch sweep: per-case makespan across the topology catalog (world 4)",
        &names,
        "us (lower=better)",
    );
    for spec in execases::CASES {
        let mut row = Vec::with_capacity(names.len());
        for name in &names {
            let p = CaseParams { topo: name.to_string(), ..Default::default() };
            let r = spec
                .build(&p)
                .and_then(|case| simulate(&case.plan, &case.topo, SimParams::default()));
            row.push(match r {
                Ok(sim) => sim.makespan_us,
                Err(_) => f64::NAN,
            });
        }
        t.push_row(spec.name, row);
    }
    Ok(t)
}

/// Headline numbers: average (geomean) and max speedup of Syncopate over
/// the best *automatic* baseline across the Fig. 8 + Fig. 9 suites.
pub fn headline(budget: Budget) -> Result<(f64, f64)> {
    let mut ratios = Vec::new();
    for t in [fig8(budget)?, fig9(budget)?] {
        let ours_col = t.col("syncopate").unwrap();
        for (_, row) in &t.rows {
            // best automatic/kernel-level baseline = max of nccl & kernel-level
            let base = row[t.col("triton+nccl").unwrap()]
                .max(row[t.col("kernel-level").unwrap()]);
            if base.is_finite() && base > 0.0 && row[ours_col].is_finite() {
                ratios.push(row[ours_col] / base);
            }
        }
    }
    let avg = crate::util::geomean(&ratios);
    let max = ratios.iter().copied().fold(0.0, f64::max);
    Ok((avg, max))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_and_fig2_static() {
        let t2 = table2();
        assert_eq!(t2.rows.len(), 3);
        // copy engine fastest at 256MiB; ldst reduces
        assert!(t2.rows[0].1[0] > t2.rows[2].1[0]);
        assert_eq!(t2.rows[2].1[2], 1.0);

        let a = fig2a();
        // utilization at 16k >= at 512 for every tile config
        let first = &a.rows[0].1;
        let last = &a.rows[a.rows.len() - 1].1;
        for (lo, hi) in first.iter().zip(last) {
            assert!(hi >= lo);
        }
        let c = fig2c();
        assert!(c.rows[0].1[0] < c.rows[c.rows.len() - 1].1[0]);
        let d = fig2d();
        // copy engine flat in SMs
        assert_eq!(d.rows[0].1[2], d.rows[6].1[2]);
    }

    #[test]
    fn fig2b_streamed_beats_partitioned() {
        let t = fig2b().unwrap();
        for (label, row) in &t.rows {
            assert!(row[0] > row[1], "{label}: streamed {} vs partitioned {}", row[0], row[1]);
        }
    }

    #[test]
    fn fig11b_split_curve_nonmonotone() {
        let t = fig11b().unwrap();
        let col: Vec<f64> = t.rows.iter().map(|(_, r)| r[1]).filter(|v| v.is_finite()).collect();
        assert!(col.len() >= 4);
        let best = col.iter().copied().fold(0.0, f64::max);
        // interior peak: neither split=1 nor the largest split is best
        assert!(col[0] < best, "split=1 must not be optimal");
        assert!(col[col.len() - 1] < best, "max split must not be optimal");
    }

    #[test]
    fn fig11c_sweet_spot() {
        let t = fig11c().unwrap();
        let col: Vec<f64> = t.rows.iter().map(|(_, r)| r[1]).collect();
        let best = col.iter().copied().fold(0.0, f64::max);
        assert!(col[0] < best || col[col.len() - 1] < best);
    }

    #[test]
    fn arch_sweep_covers_every_case_on_every_topology() {
        // acceptance: every registry exec case builds and simulates on all
        // five catalog topologies — no NaN cell anywhere
        let t = arch_sweep().unwrap();
        assert_eq!(t.columns.len(), crate::hw::catalog::names().len());
        assert_eq!(t.rows.len(), crate::coordinator::execases::CASES.len());
        for (label, row) in &t.rows {
            for (c, v) in t.columns.iter().zip(row) {
                assert!(v.is_finite() && *v > 0.0, "{label} on {c}: {v}");
            }
        }
        // the sweep must actually distinguish machines: on the compute- and
        // bandwidth-lighter a100 the ag-gemm case cannot tie h100
        let (ia, ih) = (t.col("a100_node").unwrap(), t.col("h100_node").unwrap());
        let ag = &t.rows.iter().find(|(l, _)| l == "ag-gemm").unwrap().1;
        assert!(ag[ia] > ag[ih], "a100 {} vs h100 {}", ag[ia], ag[ih]);
    }

    #[test]
    fn fig11a_backend_gap_material() {
        let t = fig11a().unwrap();
        for (label, row) in &t.rows {
            let finite: Vec<f64> = row.iter().copied().filter(|v| v.is_finite()).collect();
            let max = finite.iter().copied().fold(0.0, f64::max);
            let min = finite.iter().copied().fold(f64::INFINITY, f64::min);
            // reduce ops have only the two ld/st realizations feasible; the
            // spread across the full matrix (AG rows) must be material
            let want = if finite.len() >= 3 { 1.3 } else { 1.1 };
            assert!(max / min > want, "{label}: backend gap {max}/{min}");
        }
    }
}
