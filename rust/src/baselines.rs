//! Baseline systems (paper §6.1): the comparison points of Fig. 8/9.
//!
//! Each baseline is that system's *scheduling strategy* expressed in our
//! plan vocabulary and scored on the same simulator — the apples-to-apples
//! substitution for running the real systems on the authors' testbed
//! (DESIGN.md §1):
//!
//! * **Triton+NCCL** — sequential: full compute kernel, then a bulk library
//!   collective; kernel launches and device-wide syncs at every boundary.
//! * **Kernel-level overlap** (Alpa/Domino-style schedules) — the compute is
//!   partitioned into `k` sub-kernels overlapped with per-phase collectives
//!   on streams; every sub-launch pays launch overhead AND wave
//!   re-quantization (Fig. 2 insight 1).
//! * **Flux** — tile-granular fusion: maximal over-decomposition, ld/st
//!   communication co-located with compute.
//! * **AsyncTP** — decomposition on streams: moderate split, copy-engine
//!   transfers, separate sub-kernels.
//! * **FlashOverlap** — chunk-level signaling with an unmodified compute
//!   kernel + NCCL chunks; pays a data-reorder pass instead of a scheduler
//!   swizzle (Fig. 6b vs 6c).
//! * **TritonDistributed** — fused DSL kernel with fixed per-rank-shard
//!   chunks on specialized ld/st SMs.
//! * **ThunderKittens** — hand-fused TMA pipelines; published kernels
//!   target full-node (8-GPU) meshes only, hence the missing 4-GPU bars in
//!   Fig. 8.

use crate::backend::BackendKind;
use crate::codegen::{ExecutablePlan, PlanOp, Realization};
use crate::coordinator::operators::{compile_operator, compile_operator_barrier_sync};
use crate::coordinator::TuneConfig;
use crate::error::Result;
use crate::kernel::scheduler::{IntraOrder, SwizzlePolicy};
use crate::sim::engine::SimParams;
use crate::topo::Topology;
use crate::workload::{OpKind, OperatorInstance};

/// Kernel launch + device-sync overhead per extra launch, microseconds
/// (paper §2.3 quotes 2-3 µs per launch; a launch+sync pair lands ~5).
pub const LAUNCH_SYNC_US: f64 = 5.0;

/// HBM reorder bandwidth for FlashOverlap's explicit data-reordering pass.
pub const REORDER_GBPS: f64 = 1500.0;

/// The baseline systems of the evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Baseline {
    TritonNccl,
    KernelLevel,
    Flux,
    AsyncTp,
    FlashOverlap,
    TritonDist,
    ThunderKittens,
}

impl Baseline {
    pub const ALL: [Baseline; 7] = [
        Baseline::TritonNccl,
        Baseline::KernelLevel,
        Baseline::Flux,
        Baseline::AsyncTp,
        Baseline::FlashOverlap,
        Baseline::TritonDist,
        Baseline::ThunderKittens,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Baseline::TritonNccl => "triton+nccl",
            Baseline::KernelLevel => "kernel-level",
            Baseline::Flux => "flux",
            Baseline::AsyncTp => "async-tp",
            Baseline::FlashOverlap => "flashoverlap",
            Baseline::TritonDist => "triton-dist",
            Baseline::ThunderKittens => "thunderkittens",
        }
    }

    /// Whether the system ships an implementation for this configuration
    /// (ThunderKittens supports only 8 GPUs — Fig. 8's omitted bars).
    pub fn supports(&self, op: &OperatorInstance) -> bool {
        match self {
            Baseline::ThunderKittens => op.world == 8,
            // Flux targets GEMM+collective fusion, not attention rings
            Baseline::Flux => op.kind.is_gemm(),
            _ => true,
        }
    }
}

fn needs_reduce(op: &OperatorInstance) -> bool {
    matches!(op.kind, OpKind::GemmRs | OpKind::GemmAr)
}

/// Best feasible split for a target chunk-row count.
fn feasible_split(op: &OperatorInstance, want: usize) -> usize {
    let shard = (op.m / op.world).max(1);
    let mut s = want.min(shard).max(1);
    while s > 1 && shard % s != 0 {
        s -= 1;
    }
    s
}

/// Mark every compute segment wave-quantized (separate kernel launches).
fn quantize(plan: &mut ExecutablePlan) {
    for prog in &mut plan.per_rank {
        for op in &mut prog.ops {
            if let PlanOp::Compute(seg) = op {
                seg.quantized = true;
            }
        }
    }
}

/// Insert a launch+sync overhead before every compute segment.
fn add_launch_overheads(plan: &mut ExecutablePlan, us: f64) {
    for prog in &mut plan.per_rank {
        let mut ops = Vec::with_capacity(prog.ops.len() * 2);
        for op in prog.ops.drain(..) {
            if matches!(op, PlanOp::Compute(_)) {
                ops.push(PlanOp::Overhead { us, label: "launch+sync" });
            }
            ops.push(op);
        }
        prog.ops = ops;
    }
}

/// Prepend a flat per-rank overhead (e.g. a reorder pass).
fn add_flat_overhead(plan: &mut ExecutablePlan, us: f64, label: &'static str) {
    for prog in &mut plan.per_rank {
        prog.ops.insert(0, PlanOp::Overhead { us, label });
    }
}

/// Build the executable plan a baseline system would run for this operator.
pub fn plan(b: Baseline, op: &OperatorInstance, topo: &Topology) -> Result<(ExecutablePlan, SimParams)> {
    let reduce = needs_reduce(op);
    match b {
        Baseline::TritonNccl => {
            // compute fully, then one bulk collective; nothing overlaps
            let cfg = TuneConfig {
                split: 1,
                real: Realization::new(BackendKind::NcclBulk, 20),
                swizzle: SwizzlePolicy::RowMajor,
                ..Default::default()
            };
            let (mut p, params) = compile_operator_barrier_sync(op, &cfg, topo)?;
            quantize(&mut p);
            add_launch_overheads(&mut p, LAUNCH_SYNC_US);
            Ok((p, params))
        }
        Baseline::KernelLevel => {
            if op.kind == OpKind::AgGemm {
                return phased_ag_gemm(op, topo, feasible_split(op, 4), true);
            }
            // other patterns: modest stream decomposition with per-phase
            // launches + wave re-quantization (one phase per shard for
            // attention rings: a kernel launch per ring step)
            let cfg = TuneConfig {
                split: if op.kind.is_gemm() { feasible_split(op, 2) } else { 1 },
                real: Realization::new(BackendKind::NcclBulk, 20),
                swizzle: SwizzlePolicy::ChunkMajor { intra: IntraOrder::RowMajor },
                ..Default::default()
            };
            let (mut p, params) = compile_operator(op, &cfg, topo)?;
            quantize(&mut p);
            add_launch_overheads(&mut p, LAUNCH_SYNC_US);
            Ok((p, params))
        }
        Baseline::Flux => {
            // tile-granular fused over-decomposition, co-located ld/st
            let cfg = TuneConfig {
                split: feasible_split(op, 16),
                real: Realization::new(BackendKind::LdStColocated, 32),
                swizzle: SwizzlePolicy::ChunkMajor { intra: IntraOrder::RowMajor },
                ..Default::default()
            };
            compile_operator(op, &cfg, topo)
        }
        Baseline::AsyncTp => {
            // stream decomposition: moderate split, copy engine (or NCCL
            // when the pattern reduces), separate sub-kernels
            let backend = if reduce {
                Realization::new(BackendKind::NcclBulk, 20)
            } else {
                Realization::new(BackendKind::CopyEngine, 0)
            };
            let cfg = TuneConfig {
                split: feasible_split(op, 4),
                real: backend,
                swizzle: SwizzlePolicy::ChunkMajor { intra: IntraOrder::RowMajor },
                ..Default::default()
            };
            let (mut p, params) = compile_operator(op, &cfg, topo)?;
            quantize(&mut p);
            add_launch_overheads(&mut p, LAUNCH_SYNC_US);
            Ok((p, params))
        }
        Baseline::FlashOverlap => {
            // fused compute with chunk signals + NCCL chunks, but the
            // comm/compute layout mismatch is resolved by an explicit
            // reorder pass (Fig. 6b), not a scheduler swizzle
            let cfg = TuneConfig {
                split: feasible_split(op, 4),
                real: Realization::new(BackendKind::NcclBulk, 20),
                swizzle: SwizzlePolicy::RowMajor,
                ..Default::default()
            };
            let (mut p, params) = compile_operator(op, &cfg, topo)?;
            let reorder_us =
                (op.comm_bytes() as f64 / op.world as f64) / (REORDER_GBPS * 1e3);
            add_flat_overhead(&mut p, reorder_us + LAUNCH_SYNC_US, "reorder-pass");
            Ok((p, params))
        }
        Baseline::TritonDist => {
            // fused DSL kernel, fixed one-chunk-per-shard, specialized SMs
            let cfg = TuneConfig {
                split: 1,
                real: Realization::new(BackendKind::LdStSpecialized, 16),
                swizzle: SwizzlePolicy::ChunkMajor { intra: IntraOrder::RowMajor },
                ..Default::default()
            };
            compile_operator(op, &cfg, topo)
        }
        Baseline::ThunderKittens => {
            // hand-fused TMA pipeline (ld/st when the pattern reduces)
            let backend = if reduce {
                Realization::new(BackendKind::LdStColocated, 32)
            } else {
                Realization::new(BackendKind::TmaColocated, 16)
            };
            let cfg = TuneConfig {
                split: feasible_split(op, 2),
                real: backend,
                swizzle: SwizzlePolicy::ChunkMajor { intra: IntraOrder::Snake },
                ..Default::default()
            };
            compile_operator(op, &cfg, topo)
        }
    }
}

/// Megatron/Alpa-style k-phase AG-GEMM: partition M into `k` phases; phase
/// p AllGathers piece p of every shard (on a comm stream) while the GEMM of
/// phase p-1 runs. With `partitioned = true` each phase is its own kernel
/// launch — wave-quantized plus launch overhead (the Fig. 1 top timeline);
/// with `false` the phases are segments of one streamed persistent kernel
/// over the *identical* communication schedule. The pair is exactly the
/// Fig. 2(b) comparison.
pub fn phased_ag_gemm(
    op: &OperatorInstance,
    topo: &Topology,
    k: usize,
    partitioned: bool,
) -> Result<(ExecutablePlan, SimParams)> {
    use crate::chunk::TensorTable;
    use crate::codegen::{compile, RankComputeInput};
    use crate::depgraph::{plan_rank_sync, ChunkTileMap};
    use crate::kernel::grid::TileGrid;
    use crate::kernel::scheduler::TileScheduler;
    use crate::schedule::OpRef;
    use std::collections::HashMap;

    let w = op.world;
    let shard = op.m / w;
    let piece = shard / k;
    let cfg = TuneConfig::default();
    let mut table = TensorTable::new();
    let x = table.declare("x", &[op.m, op.k], op.dtype)?;
    // One bulk NCCL AllGather *call* per phase on the comm stream: each
    // rank receives (w-1)·piece rows per call. Modeled as one pull per rank
    // per phase whose byte count equals the per-rank ring traffic; calls
    // queue on the device's comm engine (stream semantics). The region is
    // a synthetic stand-in with the right size — baselines are sim-only.
    let mut sched = crate::schedule::CommSchedule::new(w, table.clone());
    for rank in 0..w {
        for p in 0..k {
            let rows = (w - 1) * piece;
            let region = crate::chunk::Region::rows(p * rows, rows, op.k);
            let c = crate::chunk::Chunk::new(x, region);
            sched.add_op(
                rank,
                crate::schedule::CommOp::P2p {
                    kind: crate::schedule::TransferKind::Pull,
                    peer: (rank + w - 1) % w,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps: vec![],
                },
            )?;
        }
    }
    let grid = TileGrid::gemm(op.m, op.n, cfg.block_m, cfg.block_n)?;

    let flops_rank = op.flops() / w as f64;
    let tile_flops = vec![flops_rank / grid.num_tiles() as f64; grid.num_tiles()];
    // phase of a tile = piece index of its M rows within its shard
    let phase_of = |tile: usize| -> usize {
        let c = grid.coords(tile).expect("in range");
        let (m0, _) = grid.axis_span(0, c[0]);
        ((m0 % shard) / piece).min(k - 1)
    };

    let mut inputs = Vec::with_capacity(w);
    for rank in 0..w {
        // consumers: phase p's collective feeds every tile of phase p
        let mut map = ChunkTileMap::default();
        for p in 0..k {
            let tiles: Vec<usize> =
                (0..grid.num_tiles()).filter(|&t| phase_of(t) == p).collect();
            map.consumers.insert(OpRef { rank, index: p }, tiles);
        }
        // order: phases ascending (own-shard tiles share the phase of their
        // piece — gathered pieces of all shards land together)
        let mut order: Vec<usize> = (0..grid.num_tiles()).collect();
        order.sort_by_key(|&t| (phase_of(t), t));
        let order = TileScheduler { order };
        let sync = plan_rank_sync(rank, &sched, &order, &map)?;
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops: tile_flops.clone(),
            tile_calls: HashMap::new(),
        });
    }
    let (mut plan, params) = (
        compile(&sched, &inputs, Realization::new(BackendKind::NcclBulk, 20), topo)?,
        SimParams { mxu_eff: crate::sim::waves::mxu_efficiency(cfg.block_m, cfg.block_n, cfg.block_k) },
    );
    if partitioned {
        quantize(&mut plan);
        add_launch_overheads(&mut plan, LAUNCH_SYNC_US);
    }
    Ok((plan, params))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::simulate;
    use crate::workload::{OperatorInstance, LLAMA3_8B};

    fn topo(w: usize) -> Topology {
        crate::hw::catalog::topology("h100_node", w).unwrap()
    }

    #[test]
    fn all_baselines_plan_and_simulate_ag_gemm() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 8);
        for b in Baseline::ALL {
            if !b.supports(&op) {
                continue;
            }
            let (p, params) = plan(b, &op, &topo(8)).unwrap_or_else(|e| panic!("{b:?}: {e}"));
            let r = simulate(&p, &topo(8), params).unwrap();
            assert!(r.makespan_us > 0.0, "{b:?}");
        }
    }

    #[test]
    fn reduce_ops_get_reduce_capable_backends() {
        let op = OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_8B, 4096, 8);
        for b in Baseline::ALL {
            if !b.supports(&op) {
                continue;
            }
            let r = plan(b, &op, &topo(8));
            assert!(r.is_ok(), "{b:?}: {:?}", r.err());
        }
    }

    #[test]
    fn thunderkittens_only_on_8() {
        let op4 = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let op8 = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 8);
        assert!(!Baseline::ThunderKittens.supports(&op4));
        assert!(Baseline::ThunderKittens.supports(&op8));
    }

    #[test]
    fn sequential_is_slowest_fused_among_fastest() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 8192, 8);
        let t = topo(8);
        let time = |b: Baseline| {
            let (p, params) = plan(b, &op, &t).unwrap();
            simulate(&p, &t, params).unwrap().makespan_us
        };
        let seq = time(Baseline::TritonNccl);
        let kl = time(Baseline::KernelLevel);
        let fused_best = [Baseline::Flux, Baseline::TritonDist, Baseline::ThunderKittens]
            .into_iter()
            .map(time)
            .fold(f64::INFINITY, f64::min);
        // kernel-level overlap beats sequential; fused beats kernel-level
        assert!(kl < seq, "kernel-level {kl} vs sequential {seq}");
        assert!(fused_best < kl, "fused {fused_best} vs kernel-level {kl}");
    }

    #[test]
    fn launch_overheads_present_in_partitioned_baselines() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let (p, _) = plan(Baseline::KernelLevel, &op, &topo(4)).unwrap();
        let overheads = p.per_rank[0]
            .ops
            .iter()
            .filter(|o| matches!(o, PlanOp::Overhead { .. }))
            .count();
        assert!(overheads >= 2, "{overheads}");
        let (pf, _) = plan(Baseline::Flux, &op, &topo(4)).unwrap();
        let of = pf.per_rank[0]
            .ops
            .iter()
            .filter(|o| matches!(o, PlanOp::Overhead { .. }))
            .count();
        assert_eq!(of, 0, "fused baseline must not pay per-phase launches");
    }

    #[test]
    fn feasible_split_respects_divisibility() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        // shard = 1024 rows: 16 divides
        assert_eq!(feasible_split(&op, 16), 16);
        let mut odd = op;
        odd.m = 4 * 17; // shard 17 rows, prime
        assert_eq!(feasible_split(&odd, 4), 1);
    }
}
