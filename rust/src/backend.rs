//! Communication backend models (paper §2.3, Tbl. 2, Fig. 7).
//!
//! The same logical chunk transfer can be realized by five mechanisms that
//! differ in who drives the copy, what they can express, and how bandwidth
//! scales with transfer size and SM allocation:
//!
//! | realization          | driver      | launch        | reduce | peak     |
//! |----------------------|-------------|---------------|--------|----------|
//! | `CopyEngine`         | DMA engine  | host, ~2.5 µs | no     | ~400 GB/s|
//! | `TmaSpecialized`     | ded. SMs    | instr, ~0.5 µs| no     | ~300 GB/s|
//! | `TmaColocated`       | compute SMs | instr, ~0.5 µs| no     | ~300 GB/s|
//! | `LdStSpecialized`    | ded. SMs    | instr, ~0.3 µs| YES    | ~200 GB/s|
//! | `LdStColocated`      | compute SMs | instr, ~0.3 µs| YES    | ~160 GB/s|
//!
//! Curves are calibrated to the paper's qualitative shapes (Fig. 2c/2d):
//! bandwidth ramps with transfer size toward a backend-specific peak
//! (half-saturation constants differ by an order of magnitude), SM-driven
//! backends scale with the number of issuing SMs, and copy engines pay a
//! per-contiguous-piece host launch that collapses effective bandwidth for
//! strided tensors.
//!
//! The tables in this module ([`caps`]/[`curve`]) are the **H100/NVLink
//! reference calibration**. The data-driven store is [`crate::hw::Arch`]:
//! every [`crate::topo::Topology`] carries one, sim/codegen/autotune query
//! through it, and `.topo` descriptions override these numbers per machine
//! shape without code edits. The `*_with` functions below hold the shared
//! math, parameterized by an explicit [`Curve`]/[`Caps`], so the reference
//! wrappers and the arch-aware paths cannot drift apart.

use crate::error::{Error, Result};
use crate::topo::{LinkLevel, LinkSpec};

/// The five chunk-transfer realizations of Fig. 7 (plus the bulk-NCCL
/// collective used by baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BackendKind {
    /// Dedicated DMA copy engine, host-launched, contiguous-only.
    CopyEngine,
    /// Tensor Memory Accelerator issued from dedicated communication SMs.
    TmaSpecialized,
    /// TMA issued from the compute SMs themselves (borrows cycles).
    TmaColocated,
    /// CUDA-core load/store from dedicated SMs (NVSHMEM-style; supports
    /// switch-based reduction — NVLS/SHARP).
    LdStSpecialized,
    /// CUDA-core load/store co-located with compute.
    LdStColocated,
    /// Bulk library collective (NCCL) — baseline-only realization; runs as
    /// a separate kernel with its own launch + full-device sync.
    NcclBulk,
}

impl BackendKind {
    /// All realizations the autotuner may instantiate for a fused kernel.
    pub const TUNABLE: [BackendKind; 5] = [
        BackendKind::CopyEngine,
        BackendKind::TmaSpecialized,
        BackendKind::TmaColocated,
        BackendKind::LdStSpecialized,
        BackendKind::LdStColocated,
    ];

    /// Every realization, including the baseline-only bulk collective —
    /// the row set of the capability matrix ([`crate::hw::Arch`]).
    pub const ALL: [BackendKind; 6] = [
        BackendKind::CopyEngine,
        BackendKind::TmaSpecialized,
        BackendKind::TmaColocated,
        BackendKind::LdStSpecialized,
        BackendKind::LdStColocated,
        BackendKind::NcclBulk,
    ];

    /// Dense index into [`BackendKind::ALL`] (the arch table row).
    pub fn index(self) -> usize {
        match self {
            BackendKind::CopyEngine => 0,
            BackendKind::TmaSpecialized => 1,
            BackendKind::TmaColocated => 2,
            BackendKind::LdStSpecialized => 3,
            BackendKind::LdStColocated => 4,
            BackendKind::NcclBulk => 5,
        }
    }

    /// Inverse of [`BackendKind::name`] (the `.topo` format's lookup).
    pub fn by_name(name: &str) -> Option<BackendKind> {
        Self::ALL.into_iter().find(|b| b.name() == name)
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::CopyEngine => "copy-engine",
            BackendKind::TmaSpecialized => "tma-specialized",
            BackendKind::TmaColocated => "tma-colocated",
            BackendKind::LdStSpecialized => "ldst-specialized",
            BackendKind::LdStColocated => "ldst-colocated",
            BackendKind::NcclBulk => "nccl-bulk",
        }
    }
}

/// Capability matrix (Tbl. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Caps {
    /// Each transfer must be one contiguous span (strided regions decompose
    /// into per-piece launches).
    pub contiguous_only: bool,
    /// Can accumulate into the destination (in-network / fibre reduction).
    pub supports_reduce: bool,
    /// Usable across node boundaries.
    pub inter_node: bool,
    /// Statically reserves SMs for the whole kernel (vs borrowing).
    pub dedicated_sms: bool,
    /// Driven by host API (kernel-launch-like overhead per piece).
    pub host_launched: bool,
}

/// Capability matrix lookup.
pub fn caps(kind: BackendKind) -> Caps {
    match kind {
        BackendKind::CopyEngine => Caps {
            contiguous_only: true,
            supports_reduce: false,
            inter_node: false,
            dedicated_sms: false,
            host_launched: true,
        },
        BackendKind::TmaSpecialized => Caps {
            contiguous_only: false,
            supports_reduce: false,
            inter_node: false,
            dedicated_sms: true,
            host_launched: false,
        },
        BackendKind::TmaColocated => Caps {
            contiguous_only: false,
            supports_reduce: false,
            inter_node: false,
            dedicated_sms: false,
            host_launched: false,
        },
        BackendKind::LdStSpecialized => Caps {
            contiguous_only: false,
            supports_reduce: true,
            inter_node: true,
            dedicated_sms: true,
            host_launched: false,
        },
        BackendKind::LdStColocated => Caps {
            contiguous_only: false,
            supports_reduce: true,
            inter_node: true,
            dedicated_sms: false,
            host_launched: false,
        },
        BackendKind::NcclBulk => Caps {
            contiguous_only: false,
            supports_reduce: true,
            inter_node: true,
            dedicated_sms: true,
            host_launched: true,
        },
    }
}

/// Tuning curve constants per backend.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Curve {
    /// Peak unidirectional bandwidth, GB/s (before link clamping).
    pub peak_gbps: f64,
    /// Transfer size at which half of peak is reached, bytes.
    pub half_size: f64,
    /// Per-transfer (or per-piece, if host-launched) issue overhead, µs.
    pub issue_us: f64,
    /// SMs needed to reach peak (0 = no SM involvement).
    pub sms_for_peak: usize,
}

/// Curve constants (H100/NVLink calibration; §2.3 numbers).
pub fn curve(kind: BackendKind) -> Curve {
    match kind {
        BackendKind::CopyEngine => Curve {
            peak_gbps: 400.0,
            half_size: 4.0 * 1024.0 * 1024.0,
            issue_us: 2.5,
            sms_for_peak: 0,
        },
        BackendKind::TmaSpecialized | BackendKind::TmaColocated => Curve {
            peak_gbps: 300.0,
            half_size: 512.0 * 1024.0,
            issue_us: 0.5,
            sms_for_peak: 16,
        },
        // ld/st peaks calibrated to NVSHMEM-style fused kernels on NVLink
        // (ParallelKittens reports near-link rates); NCCL's bulk busbw sits
        // between ld/st and the copy engine — NCCL is itself ld/st-driven,
        // so these must stay mutually consistent.
        BackendKind::LdStSpecialized => Curve {
            peak_gbps: 280.0,
            half_size: 128.0 * 1024.0,
            issue_us: 0.3,
            sms_for_peak: 32,
        },
        BackendKind::LdStColocated => Curve {
            peak_gbps: 240.0,
            half_size: 128.0 * 1024.0,
            issue_us: 0.3,
            sms_for_peak: 32,
        },
        BackendKind::NcclBulk => Curve {
            peak_gbps: 320.0,
            half_size: 8.0 * 1024.0 * 1024.0,
            issue_us: 8.0, // kernel launch + protocol setup
            sms_for_peak: 20,
        },
    }
}

/// Effective bandwidth (GB/s) under an explicit curve — the one place the
/// size-ramp x SM-ramp x link-clamp model lives. [`crate::hw::Arch`] and the
/// reference wrapper below both route here.
pub fn bandwidth_with(c: Curve, bytes: usize, comm_sms: usize, link: LinkSpec) -> f64 {
    let size_ramp = bytes as f64 / (bytes as f64 + c.half_size);
    let sm_ramp = if c.sms_for_peak == 0 {
        1.0
    } else {
        (comm_sms as f64 / c.sms_for_peak as f64).min(1.0)
    };
    (c.peak_gbps * size_ramp * sm_ramp).min(link.bw_gbps)
}

/// Transfer wall-clock under an explicit curve + host-launch flag.
///
/// `pieces` is the number of contiguous spans the chunk's region decomposes
/// into: host-launched backends pay `issue_us` *per piece*; SM backends pay
/// it once (descriptors handle striding).
pub fn transfer_time_with(
    c: Curve,
    host_launched: bool,
    bytes: usize,
    pieces: usize,
    comm_sms: usize,
    link: LinkSpec,
) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    let launches = if host_launched { pieces.max(1) } else { 1 };
    // Host-launched engines saturate per piece (each piece is an independent
    // transfer); descriptor-based SM backends stride in hardware and see the
    // full chunk size.
    let ramp_bytes = if host_launched { bytes / pieces.max(1) } else { bytes };
    let bw = bandwidth_with(c, ramp_bytes.max(1), comm_sms, link);
    let wire_us = bytes as f64 / (bw * 1e3); // GB/s == 1e3 bytes/µs
    launches as f64 * c.issue_us + link.lat_us + wire_us
}

/// Feasibility rules under an explicit capability row (`sm_driven` comes
/// from the matching curve's `sms_for_peak > 0`).
pub fn check_feasible_with(
    kind: BackendKind,
    c: Caps,
    sm_driven: bool,
    needs_reduce: bool,
    link_level: LinkLevel,
    comm_sms: usize,
) -> Result<()> {
    if needs_reduce && !c.supports_reduce {
        return Err(Error::Backend(format!(
            "{} cannot perform reductions (needed by this transfer)",
            kind.name()
        )));
    }
    if link_level == LinkLevel::InterNode && !c.inter_node {
        return Err(Error::Backend(format!(
            "{} does not support inter-node transfers",
            kind.name()
        )));
    }
    if sm_driven && comm_sms == 0 {
        return Err(Error::Backend(format!(
            "{} is SM-driven but comm_sms == 0",
            kind.name()
        )));
    }
    if !sm_driven && comm_sms != 0 {
        return Err(Error::Backend(format!(
            "{} takes no SMs but comm_sms == {comm_sms}",
            kind.name()
        )));
    }
    Ok(())
}

/// Effective bandwidth (GB/s) for one transfer of `bytes` with `comm_sms`
/// issuing SMs over `link`, clamped by link capacity — H100 reference
/// calibration. Arch-aware callers use [`crate::hw::Arch::effective_bandwidth_gbps`].
pub fn effective_bandwidth_gbps(
    kind: BackendKind,
    bytes: usize,
    comm_sms: usize,
    link: LinkSpec,
) -> f64 {
    bandwidth_with(curve(kind), bytes, comm_sms, link)
}

/// Wall-clock for one logical chunk transfer, microseconds — H100
/// reference calibration. Arch-aware callers use
/// [`crate::hw::Arch::transfer_time_us`].
pub fn transfer_time_us(
    kind: BackendKind,
    bytes: usize,
    pieces: usize,
    comm_sms: usize,
    link: LinkSpec,
) -> f64 {
    transfer_time_with(curve(kind), caps(kind).host_launched, bytes, pieces, comm_sms, link)
}

/// Validate a backend choice against the needs of a specific transfer —
/// H100 reference calibration. The autotuner prunes through the arch-aware
/// [`crate::hw::Arch::check_feasible`] (§5.3: "prunes configurations that
/// would violate these hardware limits").
pub fn check_feasible(
    kind: BackendKind,
    needs_reduce: bool,
    link_level: LinkLevel,
    comm_sms: usize,
) -> Result<()> {
    check_feasible_with(
        kind,
        caps(kind),
        curve(kind).sms_for_peak > 0,
        needs_reduce,
        link_level,
        comm_sms,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nvlink() -> LinkSpec {
        crate::hw::catalog::topology("h100_node", 8).unwrap().intra
    }

    #[test]
    fn all_covers_tunable_plus_nccl_and_indexes_densely() {
        assert_eq!(BackendKind::ALL.len(), BackendKind::TUNABLE.len() + 1);
        for (i, b) in BackendKind::ALL.into_iter().enumerate() {
            assert_eq!(b.index(), i);
            assert_eq!(BackendKind::by_name(b.name()), Some(b));
        }
        assert_eq!(BackendKind::by_name("warp-drive"), None);
    }

    #[test]
    fn caps_match_table2() {
        assert!(caps(BackendKind::CopyEngine).host_launched);
        assert!(caps(BackendKind::CopyEngine).contiguous_only);
        assert!(!caps(BackendKind::CopyEngine).supports_reduce);
        assert!(!caps(BackendKind::TmaSpecialized).supports_reduce);
        assert!(caps(BackendKind::LdStSpecialized).supports_reduce);
        assert!(caps(BackendKind::LdStColocated).supports_reduce);
        assert!(caps(BackendKind::LdStSpecialized).inter_node);
        assert!(!caps(BackendKind::TmaColocated).inter_node);
    }

    #[test]
    fn bandwidth_ramps_with_size() {
        let l = nvlink();
        let small = effective_bandwidth_gbps(BackendKind::CopyEngine, 64 * 1024, 0, l);
        let big = effective_bandwidth_gbps(BackendKind::CopyEngine, 256 << 20, 0, l);
        assert!(small < 0.2 * big, "small={small} big={big}");
        assert!(big > 380.0 && big <= 400.0);
    }

    #[test]
    fn bandwidth_ordering_at_peak_matches_paper() {
        // CopyEngine VVV > TMA VV > LdSt V at large sizes (Tbl. 2)
        let l = nvlink();
        let sz = 256 << 20;
        let ce = effective_bandwidth_gbps(BackendKind::CopyEngine, sz, 0, l);
        let tma = effective_bandwidth_gbps(BackendKind::TmaSpecialized, sz, 16, l);
        let ldst = effective_bandwidth_gbps(BackendKind::LdStSpecialized, sz, 32, l);
        assert!(ce > tma && tma > ldst, "{ce} {tma} {ldst}");
    }

    #[test]
    fn ldst_reaches_peak_at_smaller_sizes() {
        // Fig 2c: backends have different sweet spots — ld/st saturates at
        // smaller messages than the copy engine.
        let l = nvlink();
        let sz = 1 << 20; // 1 MiB
        let ce_frac = effective_bandwidth_gbps(BackendKind::CopyEngine, sz, 0, l)
            / curve(BackendKind::CopyEngine).peak_gbps;
        let ldst_frac = effective_bandwidth_gbps(BackendKind::LdStSpecialized, sz, 32, l)
            / curve(BackendKind::LdStSpecialized).peak_gbps;
        assert!(ldst_frac > ce_frac);
    }

    #[test]
    fn sm_scaling_fig2d() {
        let l = nvlink();
        let sz = 64 << 20;
        let bw4 = effective_bandwidth_gbps(BackendKind::TmaSpecialized, sz, 4, l);
        let bw16 = effective_bandwidth_gbps(BackendKind::TmaSpecialized, sz, 16, l);
        let bw32 = effective_bandwidth_gbps(BackendKind::TmaSpecialized, sz, 32, l);
        assert!(bw4 < bw16, "TMA must scale up to ~16 SMs");
        assert!((bw32 - bw16).abs() < 1.0, "TMA saturates at 16 SMs");
        // copy engine ignores SMs entirely
        let ce0 = effective_bandwidth_gbps(BackendKind::CopyEngine, sz, 0, l);
        let ce8 = effective_bandwidth_gbps(BackendKind::CopyEngine, sz, 8, l);
        assert_eq!(ce0, ce8);
    }

    #[test]
    fn link_clamps_bandwidth() {
        let slow = LinkSpec { level: LinkLevel::InterNode, bw_gbps: 50.0, lat_us: 5.0 };
        let bw = effective_bandwidth_gbps(BackendKind::LdStSpecialized, 256 << 20, 32, slow);
        assert!(bw <= 50.0);
    }

    #[test]
    fn strided_pieces_collapse_copy_engine() {
        // §2.3: strided tensors decompose into many transfers, each with a
        // 2-3µs launch, significantly reducing effective bandwidth.
        let l = nvlink();
        let bytes = 8 << 20;
        let one = transfer_time_us(BackendKind::CopyEngine, bytes, 1, 0, l);
        let many = transfer_time_us(BackendKind::CopyEngine, bytes, 1024, 0, l);
        assert!(many > 10.0 * one, "one={one} many={many}");
        // TMA handles striding in the descriptor: pieces don't multiply cost
        let tma_one = transfer_time_us(BackendKind::TmaSpecialized, bytes, 1, 16, l);
        let tma_many = transfer_time_us(BackendKind::TmaSpecialized, bytes, 1024, 16, l);
        assert!(tma_many < tma_one * 1.5);
    }

    #[test]
    fn transfer_time_monotone_in_bytes() {
        let l = nvlink();
        let mut prev = 0.0;
        for mb in [1usize, 4, 16, 64, 256] {
            let t = transfer_time_us(BackendKind::CopyEngine, mb << 20, 1, 0, l);
            assert!(t > prev);
            prev = t;
        }
        assert_eq!(transfer_time_us(BackendKind::CopyEngine, 0, 1, 0, l), 0.0);
    }

    #[test]
    fn feasibility_pruning() {
        use BackendKind::*;
        // reduce on TMA/copy-engine is infeasible
        assert!(check_feasible(CopyEngine, true, LinkLevel::IntraNode, 0).is_err());
        assert!(check_feasible(TmaSpecialized, true, LinkLevel::IntraNode, 16).is_err());
        assert!(check_feasible(LdStSpecialized, true, LinkLevel::IntraNode, 16).is_ok());
        // TMA cannot cross nodes
        assert!(check_feasible(TmaSpecialized, false, LinkLevel::InterNode, 16).is_err());
        assert!(check_feasible(LdStColocated, false, LinkLevel::InterNode, 8).is_ok());
        // SM-driven backends need SMs; copy engine must not take any
        assert!(check_feasible(TmaSpecialized, false, LinkLevel::IntraNode, 0).is_err());
        assert!(check_feasible(CopyEngine, false, LinkLevel::IntraNode, 4).is_err());
        assert!(check_feasible(CopyEngine, false, LinkLevel::IntraNode, 0).is_ok());
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = BackendKind::TUNABLE.iter().map(|b| b.name()).collect();
        names.push(BackendKind::NcclBulk.name());
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n);
    }
}
