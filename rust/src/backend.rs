//! Communication backend models (paper §2.3, Tbl. 2, Fig. 7).
//!
//! The same logical chunk transfer can be realized by five mechanisms that
//! differ in who drives the copy, what they can express, and how bandwidth
//! scales with transfer size and SM allocation:
//!
//! | realization          | driver      | launch        | reduce | peak     |
//! |----------------------|-------------|---------------|--------|----------|
//! | `CopyEngine`         | DMA engine  | host, ~2.5 µs | no     | ~400 GB/s|
//! | `TmaSpecialized`     | ded. SMs    | instr, ~0.5 µs| no     | ~300 GB/s|
//! | `TmaColocated`       | compute SMs | instr, ~0.5 µs| no     | ~300 GB/s|
//! | `LdStSpecialized`    | ded. SMs    | instr, ~0.3 µs| YES    | ~200 GB/s|
//! | `LdStColocated`      | compute SMs | instr, ~0.3 µs| YES    | ~160 GB/s|
//!
//! Curves are calibrated to the paper's qualitative shapes (Fig. 2c/2d):
//! bandwidth ramps with transfer size toward a backend-specific peak
//! (half-saturation constants differ by an order of magnitude), SM-driven
//! backends scale with the number of issuing SMs, and copy engines pay a
//! per-contiguous-piece host launch that collapses effective bandwidth for
//! strided tensors.

use crate::error::{Error, Result};
use crate::topo::{LinkLevel, LinkSpec};

/// The five chunk-transfer realizations of Fig. 7 (plus the bulk-NCCL
/// collective used by baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BackendKind {
    /// Dedicated DMA copy engine, host-launched, contiguous-only.
    CopyEngine,
    /// Tensor Memory Accelerator issued from dedicated communication SMs.
    TmaSpecialized,
    /// TMA issued from the compute SMs themselves (borrows cycles).
    TmaColocated,
    /// CUDA-core load/store from dedicated SMs (NVSHMEM-style; supports
    /// switch-based reduction — NVLS/SHARP).
    LdStSpecialized,
    /// CUDA-core load/store co-located with compute.
    LdStColocated,
    /// Bulk library collective (NCCL) — baseline-only realization; runs as
    /// a separate kernel with its own launch + full-device sync.
    NcclBulk,
}

impl BackendKind {
    /// All realizations the autotuner may instantiate for a fused kernel.
    pub const TUNABLE: [BackendKind; 5] = [
        BackendKind::CopyEngine,
        BackendKind::TmaSpecialized,
        BackendKind::TmaColocated,
        BackendKind::LdStSpecialized,
        BackendKind::LdStColocated,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::CopyEngine => "copy-engine",
            BackendKind::TmaSpecialized => "tma-specialized",
            BackendKind::TmaColocated => "tma-colocated",
            BackendKind::LdStSpecialized => "ldst-specialized",
            BackendKind::LdStColocated => "ldst-colocated",
            BackendKind::NcclBulk => "nccl-bulk",
        }
    }
}

/// Capability matrix (Tbl. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Caps {
    /// Each transfer must be one contiguous span (strided regions decompose
    /// into per-piece launches).
    pub contiguous_only: bool,
    /// Can accumulate into the destination (in-network / fibre reduction).
    pub supports_reduce: bool,
    /// Usable across node boundaries.
    pub inter_node: bool,
    /// Statically reserves SMs for the whole kernel (vs borrowing).
    pub dedicated_sms: bool,
    /// Driven by host API (kernel-launch-like overhead per piece).
    pub host_launched: bool,
}

/// Capability matrix lookup.
pub fn caps(kind: BackendKind) -> Caps {
    match kind {
        BackendKind::CopyEngine => Caps {
            contiguous_only: true,
            supports_reduce: false,
            inter_node: false,
            dedicated_sms: false,
            host_launched: true,
        },
        BackendKind::TmaSpecialized => Caps {
            contiguous_only: false,
            supports_reduce: false,
            inter_node: false,
            dedicated_sms: true,
            host_launched: false,
        },
        BackendKind::TmaColocated => Caps {
            contiguous_only: false,
            supports_reduce: false,
            inter_node: false,
            dedicated_sms: false,
            host_launched: false,
        },
        BackendKind::LdStSpecialized => Caps {
            contiguous_only: false,
            supports_reduce: true,
            inter_node: true,
            dedicated_sms: true,
            host_launched: false,
        },
        BackendKind::LdStColocated => Caps {
            contiguous_only: false,
            supports_reduce: true,
            inter_node: true,
            dedicated_sms: false,
            host_launched: false,
        },
        BackendKind::NcclBulk => Caps {
            contiguous_only: false,
            supports_reduce: true,
            inter_node: true,
            dedicated_sms: true,
            host_launched: true,
        },
    }
}

/// Tuning curve constants per backend.
#[derive(Debug, Clone, Copy)]
pub struct Curve {
    /// Peak unidirectional bandwidth, GB/s (before link clamping).
    pub peak_gbps: f64,
    /// Transfer size at which half of peak is reached, bytes.
    pub half_size: f64,
    /// Per-transfer (or per-piece, if host-launched) issue overhead, µs.
    pub issue_us: f64,
    /// SMs needed to reach peak (0 = no SM involvement).
    pub sms_for_peak: usize,
}

/// Curve constants (H100/NVLink calibration; §2.3 numbers).
pub fn curve(kind: BackendKind) -> Curve {
    match kind {
        BackendKind::CopyEngine => Curve {
            peak_gbps: 400.0,
            half_size: 4.0 * 1024.0 * 1024.0,
            issue_us: 2.5,
            sms_for_peak: 0,
        },
        BackendKind::TmaSpecialized | BackendKind::TmaColocated => Curve {
            peak_gbps: 300.0,
            half_size: 512.0 * 1024.0,
            issue_us: 0.5,
            sms_for_peak: 16,
        },
        // ld/st peaks calibrated to NVSHMEM-style fused kernels on NVLink
        // (ParallelKittens reports near-link rates); NCCL's bulk busbw sits
        // between ld/st and the copy engine — NCCL is itself ld/st-driven,
        // so these must stay mutually consistent.
        BackendKind::LdStSpecialized => Curve {
            peak_gbps: 280.0,
            half_size: 128.0 * 1024.0,
            issue_us: 0.3,
            sms_for_peak: 32,
        },
        BackendKind::LdStColocated => Curve {
            peak_gbps: 240.0,
            half_size: 128.0 * 1024.0,
            issue_us: 0.3,
            sms_for_peak: 32,
        },
        BackendKind::NcclBulk => Curve {
            peak_gbps: 320.0,
            half_size: 8.0 * 1024.0 * 1024.0,
            issue_us: 8.0, // kernel launch + protocol setup
            sms_for_peak: 20,
        },
    }
}

/// Effective bandwidth (GB/s) for one transfer of `bytes` with `comm_sms`
/// issuing SMs over `link`, clamped by link capacity.
pub fn effective_bandwidth_gbps(
    kind: BackendKind,
    bytes: usize,
    comm_sms: usize,
    link: LinkSpec,
) -> f64 {
    let c = curve(kind);
    let size_ramp = bytes as f64 / (bytes as f64 + c.half_size);
    let sm_ramp = if c.sms_for_peak == 0 {
        1.0
    } else {
        (comm_sms as f64 / c.sms_for_peak as f64).min(1.0)
    };
    (c.peak_gbps * size_ramp * sm_ramp).min(link.bw_gbps)
}

/// Wall-clock for one logical chunk transfer, microseconds.
///
/// `pieces` is the number of contiguous spans the chunk's region decomposes
/// into: host-launched backends pay `issue_us` *per piece*; SM backends pay
/// it once (descriptors handle striding).
pub fn transfer_time_us(
    kind: BackendKind,
    bytes: usize,
    pieces: usize,
    comm_sms: usize,
    link: LinkSpec,
) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    let c = curve(kind);
    let host = caps(kind).host_launched;
    let launches = if host { pieces.max(1) } else { 1 };
    // Host-launched engines saturate per piece (each piece is an independent
    // transfer); descriptor-based SM backends stride in hardware and see the
    // full chunk size.
    let ramp_bytes = if host { bytes / pieces.max(1) } else { bytes };
    let bw = effective_bandwidth_gbps(kind, ramp_bytes.max(1), comm_sms, link);
    let wire_us = bytes as f64 / (bw * 1e3); // GB/s == 1e3 bytes/µs
    launches as f64 * c.issue_us + link.lat_us + wire_us
}

/// Validate a backend choice against the needs of a specific transfer.
/// The autotuner uses this to prune infeasible configurations (§5.3:
/// "prunes configurations that would violate these hardware limits").
pub fn check_feasible(
    kind: BackendKind,
    needs_reduce: bool,
    link_level: LinkLevel,
    comm_sms: usize,
) -> Result<()> {
    let c = caps(kind);
    if needs_reduce && !c.supports_reduce {
        return Err(Error::Backend(format!(
            "{} cannot perform reductions (needed by this transfer)",
            kind.name()
        )));
    }
    if link_level == LinkLevel::InterNode && !c.inter_node {
        return Err(Error::Backend(format!(
            "{} does not support inter-node transfers",
            kind.name()
        )));
    }
    let needs_sms = curve(kind).sms_for_peak > 0;
    if needs_sms && comm_sms == 0 {
        return Err(Error::Backend(format!(
            "{} is SM-driven but comm_sms == 0",
            kind.name()
        )));
    }
    if !needs_sms && comm_sms != 0 {
        return Err(Error::Backend(format!(
            "{} takes no SMs but comm_sms == {comm_sms}",
            kind.name()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::Topology;

    fn nvlink() -> LinkSpec {
        Topology::h100_node(8).unwrap().intra
    }

    #[test]
    fn caps_match_table2() {
        assert!(caps(BackendKind::CopyEngine).host_launched);
        assert!(caps(BackendKind::CopyEngine).contiguous_only);
        assert!(!caps(BackendKind::CopyEngine).supports_reduce);
        assert!(!caps(BackendKind::TmaSpecialized).supports_reduce);
        assert!(caps(BackendKind::LdStSpecialized).supports_reduce);
        assert!(caps(BackendKind::LdStColocated).supports_reduce);
        assert!(caps(BackendKind::LdStSpecialized).inter_node);
        assert!(!caps(BackendKind::TmaColocated).inter_node);
    }

    #[test]
    fn bandwidth_ramps_with_size() {
        let l = nvlink();
        let small = effective_bandwidth_gbps(BackendKind::CopyEngine, 64 * 1024, 0, l);
        let big = effective_bandwidth_gbps(BackendKind::CopyEngine, 256 << 20, 0, l);
        assert!(small < 0.2 * big, "small={small} big={big}");
        assert!(big > 380.0 && big <= 400.0);
    }

    #[test]
    fn bandwidth_ordering_at_peak_matches_paper() {
        // CopyEngine VVV > TMA VV > LdSt V at large sizes (Tbl. 2)
        let l = nvlink();
        let sz = 256 << 20;
        let ce = effective_bandwidth_gbps(BackendKind::CopyEngine, sz, 0, l);
        let tma = effective_bandwidth_gbps(BackendKind::TmaSpecialized, sz, 16, l);
        let ldst = effective_bandwidth_gbps(BackendKind::LdStSpecialized, sz, 32, l);
        assert!(ce > tma && tma > ldst, "{ce} {tma} {ldst}");
    }

    #[test]
    fn ldst_reaches_peak_at_smaller_sizes() {
        // Fig 2c: backends have different sweet spots — ld/st saturates at
        // smaller messages than the copy engine.
        let l = nvlink();
        let sz = 1 << 20; // 1 MiB
        let ce_frac = effective_bandwidth_gbps(BackendKind::CopyEngine, sz, 0, l)
            / curve(BackendKind::CopyEngine).peak_gbps;
        let ldst_frac = effective_bandwidth_gbps(BackendKind::LdStSpecialized, sz, 32, l)
            / curve(BackendKind::LdStSpecialized).peak_gbps;
        assert!(ldst_frac > ce_frac);
    }

    #[test]
    fn sm_scaling_fig2d() {
        let l = nvlink();
        let sz = 64 << 20;
        let bw4 = effective_bandwidth_gbps(BackendKind::TmaSpecialized, sz, 4, l);
        let bw16 = effective_bandwidth_gbps(BackendKind::TmaSpecialized, sz, 16, l);
        let bw32 = effective_bandwidth_gbps(BackendKind::TmaSpecialized, sz, 32, l);
        assert!(bw4 < bw16, "TMA must scale up to ~16 SMs");
        assert!((bw32 - bw16).abs() < 1.0, "TMA saturates at 16 SMs");
        // copy engine ignores SMs entirely
        let ce0 = effective_bandwidth_gbps(BackendKind::CopyEngine, sz, 0, l);
        let ce8 = effective_bandwidth_gbps(BackendKind::CopyEngine, sz, 8, l);
        assert_eq!(ce0, ce8);
    }

    #[test]
    fn link_clamps_bandwidth() {
        let slow = LinkSpec { level: LinkLevel::InterNode, bw_gbps: 50.0, lat_us: 5.0 };
        let bw = effective_bandwidth_gbps(BackendKind::LdStSpecialized, 256 << 20, 32, slow);
        assert!(bw <= 50.0);
    }

    #[test]
    fn strided_pieces_collapse_copy_engine() {
        // §2.3: strided tensors decompose into many transfers, each with a
        // 2-3µs launch, significantly reducing effective bandwidth.
        let l = nvlink();
        let bytes = 8 << 20;
        let one = transfer_time_us(BackendKind::CopyEngine, bytes, 1, 0, l);
        let many = transfer_time_us(BackendKind::CopyEngine, bytes, 1024, 0, l);
        assert!(many > 10.0 * one, "one={one} many={many}");
        // TMA handles striding in the descriptor: pieces don't multiply cost
        let tma_one = transfer_time_us(BackendKind::TmaSpecialized, bytes, 1, 16, l);
        let tma_many = transfer_time_us(BackendKind::TmaSpecialized, bytes, 1024, 16, l);
        assert!(tma_many < tma_one * 1.5);
    }

    #[test]
    fn transfer_time_monotone_in_bytes() {
        let l = nvlink();
        let mut prev = 0.0;
        for mb in [1usize, 4, 16, 64, 256] {
            let t = transfer_time_us(BackendKind::CopyEngine, mb << 20, 1, 0, l);
            assert!(t > prev);
            prev = t;
        }
        assert_eq!(transfer_time_us(BackendKind::CopyEngine, 0, 1, 0, l), 0.0);
    }

    #[test]
    fn feasibility_pruning() {
        use BackendKind::*;
        // reduce on TMA/copy-engine is infeasible
        assert!(check_feasible(CopyEngine, true, LinkLevel::IntraNode, 0).is_err());
        assert!(check_feasible(TmaSpecialized, true, LinkLevel::IntraNode, 16).is_err());
        assert!(check_feasible(LdStSpecialized, true, LinkLevel::IntraNode, 16).is_ok());
        // TMA cannot cross nodes
        assert!(check_feasible(TmaSpecialized, false, LinkLevel::InterNode, 16).is_err());
        assert!(check_feasible(LdStColocated, false, LinkLevel::InterNode, 8).is_ok());
        // SM-driven backends need SMs; copy engine must not take any
        assert!(check_feasible(TmaSpecialized, false, LinkLevel::IntraNode, 0).is_err());
        assert!(check_feasible(CopyEngine, false, LinkLevel::IntraNode, 4).is_err());
        assert!(check_feasible(CopyEngine, false, LinkLevel::IntraNode, 0).is_ok());
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = BackendKind::TUNABLE.iter().map(|b| b.name()).collect();
        names.push(BackendKind::NcclBulk.name());
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n);
    }
}
