//! Small shared utilities: deterministic RNG, math helpers, formatting.
//!
//! We deliberately avoid external RNG crates: workload generation and the
//! autotuner's sampling must be bit-reproducible across runs so EXPERIMENTS.md
//! numbers regenerate exactly.

/// SplitMix64 — tiny, high-quality, deterministic PRNG.
///
/// Used for synthetic tensor data and tie-breaking in the autotuner. Not
/// cryptographic; never used for anything security-relevant.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded construction; the same seed always yields the same stream.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [-1, 1) — matches the scale of normal-ish activations.
    pub fn f32_unit(&mut self) -> f32 {
        // 24 mantissa-ish bits -> [0,1), then shift to [-1,1)
        let u = (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        2.0 * u - 1.0
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Fill a fresh Vec<f32> with unit-uniform values.
    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.f32_unit()).collect()
    }
}

/// Ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Geometric mean of positive values (0.0 for empty input).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Human-readable byte count (KiB/MiB/GiB).
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b >= K * K * K {
        format!("{:.1} GiB", b / K / K / K)
    } else if b >= K * K {
        format!("{:.1} MiB", b / K / K)
    } else if b >= K {
        format!("{:.1} KiB", b / K)
    } else {
        format!("{b} B")
    }
}

/// Human-readable duration from microseconds.
pub fn fmt_us(us: f64) -> String {
    if us >= 1e6 {
        format!("{:.2} s", us / 1e6)
    } else if us >= 1e3 {
        format!("{:.2} ms", us / 1e3)
    } else {
        format!("{us:.2} us")
    }
}

/// Escape a string for embedding in a JSON string literal — the ONE
/// escaper every hand-rolled JSON emitter in the crate uses (trace
/// export, sim timeline, metrics tables), so none of them can diverge
/// into emitting invalid JSON on control characters.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Max absolute difference between two slices (for numerics checks).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Relative L2 error ||a-b|| / max(||b||, eps).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = b.iter().map(|y| y * y).sum();
    (num.sqrt()) / den.sqrt().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escape_covers_control_chars() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("n\nt\tr\r"), "n\\nt\\tr\\r");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_unit_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f32_unit();
            assert!((-1.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn geomean_cases() {
        assert_eq!(geomean(&[]), 0.0);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
        assert_eq!(fmt_us(0.5), "0.50 us");
        assert_eq!(fmt_us(1500.0), "1.50 ms");
        assert_eq!(fmt_us(2_000_000.0), "2.00 s");
    }

    #[test]
    fn diff_helpers() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.5, 3.0];
        assert_eq!(max_abs_diff(&a, &b), 0.5);
        assert!(rel_l2(&a, &a) < 1e-12);
        assert!(rel_l2(&a, &b) > 0.0);
    }
}
