//! The `.sched` schedule DSL: format constants, keyword tables shared by
//! the parser and printer, the [`SchedBuilder`] authoring API, and content
//! hashing of canonical text.
//!
//! # Format (version `v1`)
//!
//! Line-oriented; `#` starts a comment, blank lines are ignored. Mirrors
//! the paper's Listing-2 API: per-rank ordered op lists with explicit
//! `(rank, index)` dependencies, preceded by tensor declarations.
//!
//! ```text
//! plan v1 world 4
//! tensor x f32 8x16
//!
//! rank 0:
//!   push x[0:2, 0:16] -> x[0:2, 0:16] peer 1
//!   pull x[2:4, 0:16] -> x[2:4, 0:16] peer 3 deps (3,0) (1,2)
//!   push x[4:6, 0:16] -> x[4:6, 0:16] peer 1 reduce deps (0,1)
//!   copy x[0:2, 0:16] -> x[4:6, 0:16]
//!   allgather x[0:8, 0:16] -> x[0:8, 0:16] ranks 0 1 2 3
//! rank 1:
//! ...
//! ```
//!
//! * `plan v1 world N` — the header, first significant line.
//! * `tensor NAME DTYPE D0xD1x...` — one per tensor, in id order. Dtypes:
//!   `f32`, `bf16`, `f16`.
//! * `rank N:` — starts rank `N`'s op list; every rank `0..world` appears
//!   exactly once in the canonical form (empty lists included), so
//!   `world` and `per_rank` reconstruct exactly.
//! * Op lines (leading whitespace ignored):
//!   * `push SRC -> DST peer P [reduce] [deps (r,i) ...]` — P2P defined on
//!     the source side (this rank); `DST` is written on rank `P`.
//!   * `pull SRC -> DST peer P [reduce] [deps ...]` — P2P defined on the
//!     destination side (this rank); `SRC` is read on rank `P`.
//!   * `copy SRC -> DST [deps ...]` — rank-local region copy.
//!   * `allgather|reducescatter|allreduce|alltoall|broadcast SRC -> DST
//!     ranks r0 r1 ... [deps ...]` — abstract collective (lowered before
//!     codegen).
//! * Chunks: `NAME[o0:e0, o1:e1, ...]` — per-dimension half-open index
//!   ranges against the tensor's *global* logical shape.

use crate::chunk::{Chunk, DType, Region, TensorTable};
use crate::error::{Error, Result};
use crate::schedule::{CollectiveKind, CommOp, CommSchedule, Dep, TransferKind};
use crate::topo::Rank;

/// Format version accepted and emitted (`plan v1 ...`).
pub const FORMAT_VERSION: &str = "v1";

/// Conventional file extension for schedule files.
pub const FILE_EXT: &str = "sched";

/// Canonical dtype keyword.
pub fn dtype_name(d: DType) -> &'static str {
    match d {
        DType::F32 => "f32",
        DType::BF16 => "bf16",
        DType::F16 => "f16",
    }
}

/// Inverse of [`dtype_name`].
pub fn dtype_by_name(s: &str) -> Option<DType> {
    match s {
        "f32" => Some(DType::F32),
        "bf16" => Some(DType::BF16),
        "f16" => Some(DType::F16),
        _ => None,
    }
}

/// Canonical collective keyword.
pub fn collective_name(k: CollectiveKind) -> &'static str {
    match k {
        CollectiveKind::AllGather => "allgather",
        CollectiveKind::ReduceScatter => "reducescatter",
        CollectiveKind::AllReduce => "allreduce",
        CollectiveKind::AllToAll => "alltoall",
        CollectiveKind::Broadcast => "broadcast",
    }
}

/// Inverse of [`collective_name`].
pub fn collective_by_name(s: &str) -> Option<CollectiveKind> {
    match s {
        "allgather" => Some(CollectiveKind::AllGather),
        "reducescatter" => Some(CollectiveKind::ReduceScatter),
        "allreduce" => Some(CollectiveKind::AllReduce),
        "alltoall" => Some(CollectiveKind::AllToAll),
        "broadcast" => Some(CollectiveKind::Broadcast),
        _ => None,
    }
}

/// A tensor name the format can represent unambiguously.
pub fn is_valid_tensor_name(s: &str) -> bool {
    matches!(s.chars().next(), Some(c) if c.is_ascii_alphabetic() || c == '_')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// FNV-1a 64-bit hash of a canonical printed plan, as 16 lowercase hex
/// digits. Dependency-free stand-in for a cryptographic digest; collisions
/// across a plan cache's working set are not a realistic concern and a
/// collision only costs a wrong cache hit on a *validated* plan.
pub fn content_hash(canonical: &str) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in canonical.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

/// Content hash of a schedule's canonical printed form — the coordinator's
/// plan-cache key for user-submitted plans.
pub fn plan_hash(sched: &CommSchedule) -> Result<String> {
    Ok(content_hash(&super::print::print_schedule(sched)?))
}

/// Embedded-DSL authoring API: build a [`CommSchedule`] in Rust with the
/// same vocabulary as the textual format. Every op-adding method returns
/// the new op's [`Dep`] handle so later ops can depend on it without index
/// bookkeeping (see `examples/custom_schedule.rs`).
pub struct SchedBuilder {
    world: usize,
    table: TensorTable,
    per_rank: Vec<Vec<CommOp>>,
}

impl SchedBuilder {
    pub fn new(world: usize) -> Self {
        SchedBuilder { world, table: TensorTable::new(), per_rank: vec![Vec::new(); world] }
    }

    /// Declare a tensor at its global logical shape.
    pub fn tensor(&mut self, name: &str, shape: &[usize], dtype: DType) -> Result<crate::chunk::TensorId> {
        if !is_valid_tensor_name(name) {
            return Err(Error::PlanIo(format!(
                "tensor name `{name}` is not representable in the DSL \
                 (want [A-Za-z_][A-Za-z0-9_]*)"
            )));
        }
        self.table.declare(name, shape, dtype)
    }

    fn add(&mut self, rank: Rank, op: CommOp) -> Result<Dep> {
        if rank >= self.world {
            return Err(Error::PlanIo(format!("rank {rank} out of world {}", self.world)));
        }
        self.per_rank[rank].push(op);
        Ok(Dep { rank, index: self.per_rank[rank].len() - 1 })
    }

    /// Push `chunk` from `rank` into the same region on `peer`.
    pub fn push(&mut self, rank: Rank, peer: Rank, chunk: Chunk, deps: &[Dep]) -> Result<Dep> {
        self.transfer(rank, TransferKind::Push, peer, chunk.clone(), chunk, false, deps)
    }

    /// Push-with-reduce (accumulate into the destination region).
    pub fn push_reduce(&mut self, rank: Rank, peer: Rank, chunk: Chunk, deps: &[Dep]) -> Result<Dep> {
        self.transfer(rank, TransferKind::Push, peer, chunk.clone(), chunk, true, deps)
    }

    /// Pull `chunk` from `peer` into the same region on `rank`.
    pub fn pull(&mut self, rank: Rank, peer: Rank, chunk: Chunk, deps: &[Dep]) -> Result<Dep> {
        self.transfer(rank, TransferKind::Pull, peer, chunk.clone(), chunk, false, deps)
    }

    /// Full-control P2P (distinct src/dst regions, explicit kind/reduce).
    #[allow(clippy::too_many_arguments)]
    pub fn transfer(
        &mut self,
        rank: Rank,
        kind: TransferKind,
        peer: Rank,
        src: Chunk,
        dst: Chunk,
        reduce: bool,
        deps: &[Dep],
    ) -> Result<Dep> {
        self.add(rank, CommOp::P2p { kind, peer, src, dst, reduce, deps: deps.to_vec() })
    }

    /// Rank-local region copy.
    pub fn copy(&mut self, rank: Rank, src: Chunk, dst: Chunk, deps: &[Dep]) -> Result<Dep> {
        self.add(rank, CommOp::LocalCopy { src, dst, deps: deps.to_vec() })
    }

    /// Abstract collective over a rank group.
    pub fn collective(
        &mut self,
        rank: Rank,
        kind: CollectiveKind,
        src: Chunk,
        dst: Chunk,
        ranks: &[Rank],
        deps: &[Dep],
    ) -> Result<Dep> {
        self.add(
            rank,
            CommOp::Collective {
                kind,
                src,
                dst,
                ranks: ranks.to_vec(),
                deps: deps.to_vec(),
            },
        )
    }

    /// Region helper: the `i`-th of `world` equal slabs of the tensor.
    pub fn shard(&self, tensor: crate::chunk::TensorId, axis: usize, i: usize) -> Result<Chunk> {
        let shape = self.table.get(tensor)?.shape.clone();
        Ok(Chunk::new(
            tensor,
            crate::schedule::templates::shard_region(&shape, axis, self.world, i)?,
        ))
    }

    /// Finish: assemble and structurally validate the schedule.
    pub fn build(self) -> Result<CommSchedule> {
        let sched = CommSchedule { world: self.world, tensors: self.table, per_rank: self.per_rank };
        crate::schedule::validate::validate(&sched)?;
        Ok(sched)
    }

    /// Finish without validation (for tests constructing invalid plans).
    pub fn build_unchecked(self) -> CommSchedule {
        CommSchedule { world: self.world, tensors: self.table, per_rank: self.per_rank }
    }
}

/// Region helper usable without a builder: rows `[r0, r1)` of a 2-D tensor
/// with `cols` columns (the DSL's most common chunk shape).
pub fn rows(tensor: crate::chunk::TensorId, r0: usize, r1: usize, cols: usize) -> Chunk {
    Chunk::new(tensor, Region::rows(r0, r1 - r0, cols))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keyword_tables_are_inverse() {
        for d in [DType::F32, DType::BF16, DType::F16] {
            assert_eq!(dtype_by_name(dtype_name(d)), Some(d));
        }
        for k in [
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllReduce,
            CollectiveKind::AllToAll,
            CollectiveKind::Broadcast,
        ] {
            assert_eq!(collective_by_name(collective_name(k)), Some(k));
        }
        assert_eq!(dtype_by_name("f64"), None);
        assert_eq!(collective_by_name("gather"), None);
    }

    #[test]
    fn tensor_names_validated() {
        assert!(is_valid_tensor_name("x"));
        assert!(is_valid_tensor_name("_kv_cache2"));
        assert!(!is_valid_tensor_name(""));
        assert!(!is_valid_tensor_name("2x"));
        assert!(!is_valid_tensor_name("a b"));
        assert!(!is_valid_tensor_name("a[0]"));
    }

    #[test]
    fn content_hash_is_stable_and_sensitive() {
        let a = content_hash("plan v1 world 2\n");
        assert_eq!(a.len(), 16);
        assert_eq!(a, content_hash("plan v1 world 2\n"));
        assert_ne!(a, content_hash("plan v1 world 4\n"));
    }

    #[test]
    fn builder_roundtrips_a_ring_exchange() {
        let mut b = SchedBuilder::new(2);
        let x = b.tensor("x", &[4, 8], DType::F32).unwrap();
        let d0 = b.push(0, 1, b.shard(x, 0, 0).unwrap(), &[]).unwrap();
        b.push(1, 0, b.shard(x, 0, 1).unwrap(), &[d0]).unwrap();
        let s = b.build().unwrap();
        assert_eq!(s.num_ops(), 2);
        assert_eq!(s.per_rank[1][0].deps(), &[Dep::on(0, 0)]);
    }

    #[test]
    fn builder_rejects_bad_names_and_ranks() {
        let mut b = SchedBuilder::new(2);
        assert!(b.tensor("1bad", &[4], DType::F32).is_err());
        let x = b.tensor("x", &[4, 8], DType::F32).unwrap();
        let c = b.shard(x, 0, 0).unwrap();
        assert!(b.push(5, 0, c, &[]).is_err());
    }

    #[test]
    fn plan_hash_tracks_canonical_form() {
        let mk = |world: usize| {
            let mut b = SchedBuilder::new(world);
            let x = b.tensor("x", &[4, 8], DType::F32).unwrap();
            let c = b.shard(x, 0, 0).unwrap();
            b.push(0, 1, c, &[]).unwrap();
            b.build_unchecked()
        };
        assert_eq!(plan_hash(&mk(2)).unwrap(), plan_hash(&mk(2)).unwrap());
        assert_ne!(plan_hash(&mk(2)).unwrap(), plan_hash(&mk(4)).unwrap());
    }
}
