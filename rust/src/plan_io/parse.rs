//! Hand-rolled parser for the `.sched` format (no serde in the offline
//! build).
//!
//! Accepts a superset of the canonical form — flexible whitespace, `#`
//! comments, blank lines, rank sections in any order, spaces inside
//! `( r , i )` dep tuples — and reconstructs the exact
//! [`CommSchedule`] structure, so `parse(print(s)) == s` and
//! `print(parse(text))` is canonical for any accepted `text`.
//!
//! Every error carries a `line L, col C:` prefix (1-based) pointing at the
//! offending token.

use crate::chunk::{Chunk, Region, TensorTable};
use crate::error::{Error, Result};
use crate::schedule::{CommOp, CommSchedule, Dep, TransferKind};
use crate::topo::Rank;

use super::dsl::{collective_by_name, dtype_by_name, is_valid_tensor_name, FORMAT_VERSION};

/// Parse `.sched` text into a schedule. Structural validity (dep
/// resolvability, bounds, acyclicity) is *not* checked here — run
/// [`crate::schedule::validate::validate`] on the result.
pub fn parse_schedule(text: &str) -> Result<CommSchedule> {
    let mut header: Option<usize> = None;
    let mut table = TensorTable::new();
    let mut per_rank: Vec<Vec<CommOp>> = Vec::new();
    let mut seen_rank: Vec<bool> = Vec::new();
    let mut cur_rank: Option<Rank> = None;

    for (i, raw) in text.lines().enumerate() {
        let mut cur = Cur::new(raw, i + 1);
        cur.skip_ws();
        if cur.done() {
            continue; // blank or comment-only line
        }
        let kw_col = cur.col();
        let kw = cur.word()?;
        match (kw.as_str(), header) {
            ("plan", None) => {
                let ver = cur.word()?;
                if ver != FORMAT_VERSION {
                    return Err(cur.err_at(
                        kw_col,
                        &format!("unsupported plan version `{ver}` (expected {FORMAT_VERSION})"),
                    ));
                }
                cur.keyword("world")?;
                let world = cur.number()?;
                if world == 0 {
                    return Err(cur.err_at(kw_col, "world must be > 0"));
                }
                cur.end_of_line()?;
                per_rank = vec![Vec::new(); world];
                seen_rank = vec![false; world];
                header = Some(world);
            }
            (_, None) => {
                return Err(cur.err_at(
                    kw_col,
                    &format!("expected `plan {FORMAT_VERSION} world N` header, found `{kw}`"),
                ));
            }
            ("plan", Some(_)) => {
                return Err(cur.err_at(kw_col, "duplicate `plan` header"));
            }
            ("tensor", Some(_)) => {
                if cur_rank.is_some() {
                    return Err(cur.err_at(
                        kw_col,
                        "tensor declarations must precede rank sections",
                    ));
                }
                let name_col = cur.col_after_ws();
                let name = cur.word()?;
                if !is_valid_tensor_name(&name) {
                    return Err(cur.err_at(
                        name_col,
                        &format!("invalid tensor name `{name}` (want [A-Za-z_][A-Za-z0-9_]*)"),
                    ));
                }
                let dt_col = cur.col_after_ws();
                let dt = cur.word()?;
                let dtype = dtype_by_name(&dt).ok_or_else(|| {
                    cur.err_at(dt_col, &format!("unknown dtype `{dt}` (f32|bf16|f16)"))
                })?;
                let shape = cur.shape()?;
                cur.end_of_line()?;
                table
                    .declare(&name, &shape, dtype)
                    .map_err(|e| cur.err_at(name_col, &e.to_string()))?;
            }
            ("rank", Some(world)) => {
                let n_col = cur.col_after_ws();
                let r = cur.number()?;
                if r >= world {
                    return Err(cur.err_at(n_col, &format!("rank {r} out of world {world}")));
                }
                if seen_rank[r] {
                    return Err(cur.err_at(n_col, &format!("rank {r} declared twice")));
                }
                seen_rank[r] = true;
                cur.expect(':')?;
                cur.end_of_line()?;
                cur_rank = Some(r);
            }
            (_, Some(world)) => {
                let Some(rank) = cur_rank else {
                    return Err(cur.err_at(
                        kw_col,
                        &format!("op line `{kw} ...` outside any `rank N:` section"),
                    ));
                };
                let op = parse_op(&mut cur, &kw, kw_col, world, &table)?;
                cur.end_of_line()?;
                per_rank[rank].push(op);
            }
        }
    }

    let Some(world) = header else {
        return Err(Error::PlanIo(
            "line 1, col 1: empty input (expected `plan v1 world N` header)".into(),
        ));
    };
    Ok(CommSchedule { world, tensors: table, per_rank })
}

fn parse_op(
    cur: &mut Cur<'_>,
    kw: &str,
    kw_col: usize,
    world: usize,
    table: &TensorTable,
) -> Result<CommOp> {
    match kw {
        "push" | "pull" => {
            let src = cur.chunk(table)?;
            cur.arrow()?;
            let dst = cur.chunk(table)?;
            cur.keyword("peer")?;
            let p_col = cur.col_after_ws();
            let peer = cur.number()?;
            if peer >= world {
                return Err(cur.err_at(p_col, &format!("peer {peer} out of world {world}")));
            }
            let reduce = cur.opt_keyword("reduce");
            let deps = cur.deps()?;
            let kind = if kw == "push" { TransferKind::Push } else { TransferKind::Pull };
            Ok(CommOp::P2p { kind, peer, src, dst, reduce, deps })
        }
        "copy" => {
            let src = cur.chunk(table)?;
            cur.arrow()?;
            let dst = cur.chunk(table)?;
            let deps = cur.deps()?;
            Ok(CommOp::LocalCopy { src, dst, deps })
        }
        _ => {
            let Some(kind) = collective_by_name(kw) else {
                return Err(cur.err_at(
                    kw_col,
                    &format!(
                        "unknown op `{kw}` (push|pull|copy|allgather|reducescatter|\
                         allreduce|alltoall|broadcast)"
                    ),
                ));
            };
            let src = cur.chunk(table)?;
            cur.arrow()?;
            let dst = cur.chunk(table)?;
            cur.keyword("ranks")?;
            let mut ranks = Vec::new();
            loop {
                let c = cur.col_after_ws();
                match cur.try_number() {
                    Some(r) => {
                        if r >= world {
                            return Err(
                                cur.err_at(c, &format!("group rank {r} out of world {world}"))
                            );
                        }
                        ranks.push(r);
                    }
                    None => break,
                }
            }
            if ranks.is_empty() {
                return Err(cur.err_here("expected at least one group rank after `ranks`"));
            }
            let deps = cur.deps()?;
            Ok(CommOp::Collective { kind, src, dst, ranks, deps })
        }
    }
}

/// Single-line cursor with 1-based line/col error positions.
struct Cur<'a> {
    chars: Vec<char>,
    pos: usize,
    line_no: usize,
    raw: &'a str,
}

impl<'a> Cur<'a> {
    fn new(raw: &'a str, line_no: usize) -> Self {
        // strip trailing comment (no string literals in the grammar)
        let body = match raw.find('#') {
            Some(i) => &raw[..i],
            None => raw,
        };
        Cur { chars: body.chars().collect(), pos: 0, line_no, raw }
    }

    fn done(&self) -> bool {
        self.pos >= self.chars.len()
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn col(&self) -> usize {
        self.pos + 1
    }

    /// Column of the next non-whitespace char (consumes the whitespace).
    fn col_after_ws(&mut self) -> usize {
        self.skip_ws();
        self.col()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(c) if c.is_whitespace()) {
            self.pos += 1;
        }
    }

    fn err_here(&self, msg: &str) -> Error {
        self.err_at(self.col(), msg)
    }

    fn err_at(&self, col: usize, msg: &str) -> Error {
        Error::PlanIo(format!(
            "line {}, col {col}: {msg} (in `{}`)",
            self.line_no,
            self.raw.trim_end()
        ))
    }

    fn end_of_line(&mut self) -> Result<()> {
        self.skip_ws();
        if self.done() {
            return Ok(());
        }
        let rest: String = self.chars[self.pos..].iter().collect();
        Err(self.err_here(&format!("unexpected trailing `{}`", rest.trim_end())))
    }

    fn expect(&mut self, c: char) -> Result<()> {
        self.skip_ws();
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err_here(&format!("expected `{c}`")))
        }
    }

    fn arrow(&mut self) -> Result<()> {
        self.skip_ws();
        if self.chars.get(self.pos) == Some(&'-') && self.chars.get(self.pos + 1) == Some(&'>') {
            self.pos += 2;
            Ok(())
        } else {
            Err(self.err_here("expected `->`"))
        }
    }

    fn word(&mut self) -> Result<String> {
        self.skip_ws();
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_alphanumeric() || c == '_') {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err_here("expected a word"));
        }
        Ok(self.chars[start..self.pos].iter().collect())
    }

    /// Consume the exact keyword `kw` or error.
    fn keyword(&mut self, kw: &str) -> Result<()> {
        let col = self.col_after_ws();
        let w = self.word().map_err(|_| self.err_at(col, &format!("expected `{kw}`")))?;
        if w == kw {
            Ok(())
        } else {
            Err(self.err_at(col, &format!("expected `{kw}`, found `{w}`")))
        }
    }

    /// Consume the keyword if present (returns whether it was).
    fn opt_keyword(&mut self, kw: &str) -> bool {
        let save = self.pos;
        self.skip_ws();
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_alphanumeric() || c == '_') {
            self.pos += 1;
        }
        let w: String = self.chars[start..self.pos].iter().collect();
        if w == kw {
            true
        } else {
            self.pos = save;
            false
        }
    }

    fn try_number(&mut self) -> Option<usize> {
        let save = self.pos;
        self.skip_ws();
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.pos == start {
            self.pos = save;
            return None;
        }
        let s: String = self.chars[start..self.pos].iter().collect();
        match s.parse() {
            Ok(n) => Some(n),
            Err(_) => {
                self.pos = save;
                None
            }
        }
    }

    fn number(&mut self) -> Result<usize> {
        self.skip_ws();
        let col = self.col();
        self.try_number()
            .ok_or_else(|| self.err_at(col, "expected an unsigned integer"))
    }

    /// `D0xD1x...` tensor shape.
    fn shape(&mut self) -> Result<Vec<usize>> {
        let mut dims = vec![self.number()?];
        while self.peek() == Some('x') {
            self.pos += 1;
            // no whitespace inside a shape: `8x16`, not `8 x 16`
            let col = self.col();
            let start = self.pos;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
            if self.pos == start {
                return Err(self.err_at(col, "expected a dimension after `x`"));
            }
            let s: String = self.chars[start..self.pos].iter().collect();
            dims.push(
                s.parse().map_err(|_| self.err_at(col, "expected a dimension after `x`"))?,
            );
        }
        Ok(dims)
    }

    /// `name[o0:e0, o1:e1, ...]` chunk reference.
    fn chunk(&mut self, table: &TensorTable) -> Result<Chunk> {
        let name_col = self.col_after_ws();
        let name = self.word().map_err(|_| self.err_at(name_col, "expected a tensor name"))?;
        let Some(id) = table.lookup(&name) else {
            return Err(self.err_at(name_col, &format!("unknown tensor `{name}`")));
        };
        self.expect('[')?;
        let mut offset = Vec::new();
        let mut sizes = Vec::new();
        loop {
            let lo_col = self.col_after_ws();
            let lo = self.number()?;
            self.expect(':')?;
            let hi_col = self.col_after_ws();
            let hi = self.number()?;
            if hi <= lo {
                return Err(self.err_at(
                    hi_col,
                    &format!("empty or inverted range {lo}:{hi}"),
                ));
            }
            let _ = lo_col;
            offset.push(lo);
            sizes.push(hi - lo);
            self.skip_ws();
            match self.peek() {
                Some(',') => {
                    self.pos += 1;
                }
                Some(']') => {
                    self.pos += 1;
                    break;
                }
                _ => return Err(self.err_here("expected `,` or `]` in region")),
            }
        }
        Ok(Chunk::new(id, Region { offset, sizes }))
    }

    /// Optional `deps (r,i) (r,i) ...` suffix.
    fn deps(&mut self) -> Result<Vec<Dep>> {
        if !self.opt_keyword("deps") {
            return Ok(Vec::new());
        }
        let mut deps = Vec::new();
        loop {
            self.skip_ws();
            if self.peek() != Some('(') {
                break;
            }
            self.pos += 1;
            let rank = self.number()?;
            self.expect(',')?;
            let index = self.number()?;
            self.expect(')')?;
            deps.push(Dep { rank, index });
        }
        if deps.is_empty() {
            return Err(self.err_here("expected at least one `(rank,index)` after `deps`"));
        }
        Ok(deps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::DType;
    use crate::plan_io::print::print_schedule;

    const OK: &str = "\
# a hand-written exchange
plan v1 world 2
tensor x f32 8x16

rank 0:
  push x[0:4, 0:16] -> x[0:4, 0:16] peer 1
rank 1:
  pull x[4:8, 0:16] -> x[4:8, 0:16] peer 0 deps (0,0)
";

    #[test]
    fn parses_canonical_text() {
        let s = parse_schedule(OK).unwrap();
        assert_eq!(s.world, 2);
        assert_eq!(s.num_ops(), 2);
        assert_eq!(s.tensors.get(s.tensors.lookup("x").unwrap()).unwrap().dtype, DType::F32);
        let CommOp::P2p { kind, peer, reduce, .. } = &s.per_rank[0][0] else { panic!() };
        assert_eq!(*kind, TransferKind::Push);
        assert_eq!(*peer, 1);
        assert!(!reduce);
        assert_eq!(s.per_rank[1][0].deps(), &[Dep::on(0, 0)]);
    }

    #[test]
    fn tolerates_messy_whitespace_and_comments() {
        let messy = "\
plan   v1   world 2   # header
tensor x f32 8x16
rank 1:              # empty is fine
rank 0:
    push   x[ 0:4 , 0:16 ]->x[0:4, 0:16]   peer 1   deps ( 1 , 0 )  # dep
rank_ignored_comment_not_here
";
        // the last line is an op keyword error — drop it for the happy path
        let messy = &messy[..messy.rfind("rank_ignored").unwrap()];
        let s = parse_schedule(messy).unwrap();
        assert_eq!(s.per_rank[0].len(), 1);
        assert_eq!(s.per_rank[0][0].deps(), &[Dep::on(1, 0)]);
        // re-print is canonical
        let canon = print_schedule(&s).unwrap();
        assert!(canon.contains("  push x[0:4, 0:16] -> x[0:4, 0:16] peer 1 deps (1,0)"));
    }

    fn err_of(text: &str) -> String {
        parse_schedule(text).unwrap_err().to_string()
    }

    #[test]
    fn errors_carry_line_and_col() {
        // bad header version
        let e = err_of("plan v9 world 2\n");
        assert!(e.contains("line 1, col 1") && e.contains("v9"), "{e}");
        // unknown dtype: `f64` starts at col 10
        let e = err_of("plan v1 world 2\ntensor x f64 8x16\n");
        assert!(e.contains("line 2, col 10") && e.contains("f64"), "{e}");
        // unknown tensor in an op
        let e = err_of("plan v1 world 2\nrank 0:\n  push y[0:1] -> y[0:1] peer 1\n");
        assert!(e.contains("line 3, col 8") && e.contains("unknown tensor"), "{e}");
        // op outside a rank section
        let e = err_of("plan v1 world 2\ntensor x f32 4x4\npush x[0:1, 0:4] -> x[0:1, 0:4] peer 1\n");
        assert!(e.contains("line 3") && e.contains("outside"), "{e}");
        // missing header entirely
        let e = err_of("tensor x f32 4x4\n");
        assert!(e.contains("line 1") && e.contains("header"), "{e}");
        // empty range
        let e = err_of("plan v1 world 2\ntensor x f32 4x4\nrank 0:\n  push x[2:2, 0:4] -> x[0:1, 0:4] peer 1\n");
        assert!(e.contains("line 4") && e.contains("empty or inverted"), "{e}");
        // trailing junk
        let e = err_of("plan v1 world 2 extra\n");
        assert!(e.contains("line 1") && e.contains("trailing"), "{e}");
        // rank out of world / duplicate rank
        let e = err_of("plan v1 world 2\nrank 5:\n");
        assert!(e.contains("line 2, col 6") && e.contains("out of world"), "{e}");
        let e = err_of("plan v1 world 2\nrank 0:\nrank 0:\n");
        assert!(e.contains("line 3") && e.contains("twice"), "{e}");
        // deps without tuples
        let e = err_of(
            "plan v1 world 2\ntensor x f32 4x4\nrank 0:\n  push x[0:1, 0:4] -> x[0:1, 0:4] peer 1 deps\n",
        );
        assert!(e.contains("line 4") && e.contains("(rank,index)"), "{e}");
        // peer out of world
        let e = err_of("plan v1 world 2\ntensor x f32 4x4\nrank 0:\n  push x[0:1, 0:4] -> x[0:1, 0:4] peer 9\n");
        assert!(e.contains("line 4") && e.contains("peer 9"), "{e}");
    }

    #[test]
    fn unparsed_ranks_default_to_empty() {
        let s = parse_schedule("plan v1 world 4\ntensor x f32 4x4\nrank 2:\n  copy x[0:1, 0:4] -> x[1:2, 0:4]\n").unwrap();
        assert_eq!(s.per_rank.len(), 4);
        assert_eq!(s.per_rank[2].len(), 1);
        assert!(s.per_rank[0].is_empty() && s.per_rank[3].is_empty());
    }

    #[test]
    fn collective_line_roundtrips() {
        let text = "plan v1 world 2\ntensor x f32 4x4\nrank 0:\n  allgather x[0:4, 0:4] -> x[0:4, 0:4] ranks 0 1\n";
        let s = parse_schedule(text).unwrap();
        let CommOp::Collective { kind, ranks, .. } = &s.per_rank[0][0] else { panic!() };
        assert_eq!(*kind, crate::schedule::CollectiveKind::AllGather);
        assert_eq!(ranks, &[0, 1]);
        let again = parse_schedule(&print_schedule(&s).unwrap()).unwrap();
        assert_eq!(again, s);
    }
}
