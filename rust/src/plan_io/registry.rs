//! Named plan sources: every exec-capable schedule template, every
//! baseline importer, and the fused cross-operator pipelines
//! (`crate::pipeline`), instantiated at canonical validation-scale shapes.
//!
//! One registry drives three consumers:
//! * `plan import --from NAME [--world N]` (the CLI's porting entry point),
//! * the round-trip corpus test (`rust/tests/plan_io_corpus.rs`): every
//!   source at worlds 2/4/8 must satisfy `parse(print(s)) == s` and pass
//!   `validate()`,
//! * `reports::ported`, which scores ported plans against native templates.

use crate::chunk::{DType, TensorTable};
use crate::error::{Error, Result};
use crate::schedule::{templates, CommSchedule};

use super::import;

/// Where a source's plan comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SourceKind {
    /// Native reusable template (`schedule::templates`).
    Template,
    /// Imported from a foreign stream-level plan (`plan_io::import`).
    Imported,
    /// Cross-operator pipeline fused by `crate::pipeline::fuse` — multiple
    /// stages' schedules composed into one barrier-free plan.
    Fused,
}

/// One named plan source.
pub struct PlanSource {
    pub name: &'static str,
    pub kind: SourceKind,
    pub about: &'static str,
    build: fn(usize) -> Result<CommSchedule>,
}

impl PlanSource {
    /// Instantiate at `world` ranks (canonical shape: `x[world²·2 × 16]`
    /// f32 — divisible for every template including AllToAll's `world²`
    /// block grid).
    pub fn build(&self, world: usize) -> Result<CommSchedule> {
        if world < 2 {
            return Err(Error::PlanIo(format!(
                "plan source `{}` needs world >= 2, got {world}",
                self.name
            )));
        }
        (self.build)(world)
    }
}

/// Canonical tensor table for registry-built plans.
fn canon_table(world: usize) -> Result<(TensorTable, crate::chunk::TensorId)> {
    let mut t = TensorTable::new();
    let x = t.declare("x", &[world * world * 2, 16], DType::F32)?;
    Ok((t, x))
}

macro_rules! template_source {
    ($f:path) => {
        |world| {
            let (t, x) = canon_table(world)?;
            $f(&t, x, 0, world)
        }
    };
}

/// All registered plan sources.
pub fn sources() -> Vec<PlanSource> {
    vec![
        PlanSource {
            name: "ag-ring",
            kind: SourceKind::Template,
            about: "ring AllGather (Fig. 4c): forwarding chains",
            build: template_source!(templates::all_gather_ring),
        },
        PlanSource {
            name: "ag-swizzle",
            kind: SourceKind::Template,
            about: "1-D swizzled pull AllGather (Listing 2)",
            build: template_source!(templates::all_gather_swizzle),
        },
        PlanSource {
            name: "ag-direct",
            kind: SourceKind::Template,
            about: "direct push AllGather (naive broadcast)",
            build: template_source!(templates::all_gather_direct),
        },
        PlanSource {
            name: "rs-ring",
            kind: SourceKind::Template,
            about: "ring ReduceScatter",
            build: template_source!(templates::reduce_scatter_ring),
        },
        PlanSource {
            name: "rs-direct",
            kind: SourceKind::Template,
            about: "direct ReduceScatter (owner-targeted reduce pushes)",
            build: template_source!(templates::reduce_scatter_direct),
        },
        PlanSource {
            name: "ar-partition",
            kind: SourceKind::Template,
            about: "partition AllReduce (Fig. 4d): fibre reduce + re-broadcast",
            build: template_source!(templates::all_reduce_partition),
        },
        PlanSource {
            name: "ar-rs-ag",
            kind: SourceKind::Template,
            about: "AllReduce as ring RS then ring AG",
            build: template_source!(templates::all_reduce_rs_ag),
        },
        PlanSource {
            name: "a2a",
            kind: SourceKind::Template,
            about: "AllToAll block exchange",
            build: template_source!(templates::all_to_all),
        },
        PlanSource {
            name: "ag-hier",
            kind: SourceKind::Template,
            about: "heterogeneous hierarchical AllGather (Fig. 4e), 2 nodes",
            build: |world| {
                if world % 2 != 0 {
                    return Err(Error::PlanIo(format!(
                        "ag-hier needs an even world, got {world}"
                    )));
                }
                let (t, x) = canon_table(world)?;
                let topo = crate::hw::catalog::topology_nodes("h100_multinode", 2, world)?;
                templates::all_gather_hierarchical(&t, x, 0, &topo)
            },
        },
        PlanSource {
            name: "tp-block",
            kind: SourceKind::Fused,
            about: "fused TP MLP block: AllGather(x) + ReduceScatter(y), no boundary barrier",
            build: |world| {
                let mut t1 = TensorTable::new();
                let x = t1.declare("x", &[world * world * 2, 16], DType::F32)?;
                let mut t2 = TensorTable::new();
                let y = t2.declare("y", &[world * world * 2, 16], DType::F32)?;
                let fused = crate::pipeline::fuse(&[
                    crate::pipeline::Stage::new(
                        "ag",
                        templates::all_gather_swizzle(&t1, x, 0, world)?,
                    ),
                    crate::pipeline::Stage::new(
                        "rs",
                        templates::reduce_scatter_direct(&t2, y, 0, world)?,
                    ),
                ])?;
                Ok(fused.sched)
            },
        },
        PlanSource {
            name: "moe-a2a",
            kind: SourceKind::Fused,
            about: "fused MoE block: AllToAll dispatch + inverse AllToAll combine",
            build: |world| {
                let mut t1 = TensorTable::new();
                let x = t1.declare("x", &[world * world * 2, 16], DType::F32)?;
                let mut t2 = TensorTable::new();
                let y = t2.declare("y", &[world * world * 2, 16], DType::F32)?;
                let fused = crate::pipeline::fuse(&[
                    crate::pipeline::Stage::new(
                        "dispatch",
                        templates::all_to_all(&t1, x, 0, world)?,
                    ),
                    crate::pipeline::Stage::new(
                        "combine",
                        templates::all_to_all_transpose(&t2, y, 0, world)?,
                    ),
                ])?;
                Ok(fused.sched)
            },
        },
        PlanSource {
            name: "flux-ag",
            kind: SourceKind::Imported,
            about: "Flux-style tile-granular AllGather, lifted from streams",
            build: |world| {
                let (t, x) = canon_table(world)?;
                import::flux_ag(&t, x, 0, world, 2)
            },
        },
        PlanSource {
            name: "tdist-ag",
            kind: SourceKind::Imported,
            about: "Triton-distributed-style shard AllGather, lifted from streams",
            build: |world| {
                let (t, x) = canon_table(world)?;
                import::triton_dist_ag(&t, x, 0, world)
            },
        },
    ]
}

/// Registered source names, in listing order.
pub fn names() -> Vec<&'static str> {
    sources().iter().map(|s| s.name).collect()
}

/// Build a named source; unknown names list the registry.
pub fn build(name: &str, world: usize) -> Result<CommSchedule> {
    let all = sources();
    let Some(src) = all.iter().find(|s| s.name == name) else {
        return Err(Error::PlanIo(format!(
            "unknown plan source `{name}` (known: {})",
            names().join(", ")
        )));
    };
    src.build(world)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::validate;

    #[test]
    fn every_source_builds_and_validates() {
        for src in sources() {
            for world in [2usize, 4, 8] {
                let s = src
                    .build(world)
                    .unwrap_or_else(|e| panic!("{} @ world {world}: {e}", src.name));
                validate(&s).unwrap_or_else(|e| panic!("{} @ world {world}: {e}", src.name));
                assert_eq!(s.world, world);
            }
        }
    }

    #[test]
    fn unknown_source_names_registry() {
        let e = build("nope", 4).unwrap_err().to_string();
        assert!(e.contains("unknown plan source"), "{e}");
        assert!(e.contains("ag-ring") && e.contains("tdist-ag"), "{e}");
    }

    #[test]
    fn kinds_cover_both_paths() {
        let all = sources();
        assert!(all.iter().any(|s| s.kind == SourceKind::Template));
        assert!(all.iter().any(|s| s.kind == SourceKind::Imported));
        assert!(all.iter().any(|s| s.kind == SourceKind::Fused));
        // names are unique
        let mut n = names();
        n.sort_unstable();
        n.dedup();
        assert_eq!(n.len(), all.len());
    }

    #[test]
    fn world_below_two_rejected() {
        assert!(build("ag-ring", 1).is_err());
    }
}
