//! Plan interchange: chunk schedules as a serializable, user-authorable
//! artifact (DESIGN.md §11).
//!
//! The paper claims chunk-level plans can be "ported from existing
//! distributed compilers, written directly by users, or instantiated from
//! reusable templates". `schedule::templates` covers the third path; this
//! subsystem adds the first two:
//!
//! * [`dsl`] — the `.sched` textual format (version, keyword tables, the
//!   [`dsl::SchedBuilder`] authoring API, content hashing of canonical
//!   text).
//! * [`print`] — the canonical pretty-printer. `print(parse(print(s)))`
//!   is bit-identical to `print(s)`, and `parse(print(s)) == s`
//!   structurally for every template and importer output (enforced by
//!   `rust/tests/plan_io_corpus.rs`).
//! * [`parse`] — a dependency-free hand-rolled parser (the offline build
//!   carries no serde). Errors carry `line L, col C:` positions.
//! * [`import`] — lifts *stream-level* plans, the representation existing
//!   distributed runtimes actually expose (ordered per-stream transfer
//!   lists, no chunk deps), into genuine [`crate::schedule::CommSchedule`]s
//!   by turning stream order into explicit `(rank, index)` dependencies.
//!   Ships Flux-style and Triton-distributed-style AllGather importers
//!   matching the baselines of `crate::baselines`.
//! * [`registry`] — named plan sources (every exec-capable template plus
//!   every importer) at canonical validation-scale shapes; drives
//!   `plan import --from NAME`, the round-trip corpus test, and
//!   `reports::ported`.
//!
//! Serving: a parsed user plan flows through `schedule::validate` →
//! restricted autotune ([`crate::autotune::tune_user_plan`]: intra-chunk
//! knobs only, the split is fixed by the plan's own chunking) →
//! [`crate::codegen::compile_comm_only`] → `exec::`, cached in the
//! coordinator's plan cache under [`dsl::plan_hash`] of the canonical
//! printed form (`coordinator::service`).

pub mod dsl;
pub mod import;
pub mod parse;
pub mod print;
pub mod registry;

pub use dsl::{content_hash, plan_hash, SchedBuilder, FILE_EXT, FORMAT_VERSION};
pub use import::{lift, StreamOp, StreamPlan};
pub use parse::parse_schedule;
pub use print::print_schedule;
