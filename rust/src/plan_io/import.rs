//! Importers: lift *stream-level* communication plans — the representation
//! existing distributed compilers and runtimes actually expose — into
//! genuine chunk schedules (the paper's "ported from existing distributed
//! compilers" path).
//!
//! A stream-level plan has no chunk dependencies: each rank owns a handful
//! of streams (CUDA streams, copy-engine queues, a DSL kernel's ld/st
//! warpgroup), and ordering exists only *within* a stream. [`lift`] turns
//! that implicit ordering into explicit `(rank, index)` dependency chains,
//! after which the plan is a first-class [`CommSchedule`]: it validates,
//! splits, simulates, and executes exactly like a native template — which
//! is what lets `reports::ported` and the `ag-gemm-flux` /
//! `ag-gemm-tdist` exec cases score ported plans like-for-like.
//!
//! Two concrete importers mirror the baseline systems of
//! [`crate::baselines`]:
//!
//! * [`flux_ag`] — Flux-style tile-granular over-decomposition: every
//!   consumer pulls every remote shard in tile-sized pieces, one stream
//!   per peer (Flux fuses the loads into the GEMM; the *transfer order
//!   per peer* is the stream).
//! * [`triton_dist_ag`] — Triton-distributed-style: one chunk per rank
//!   shard, pushed by the owner on its single specialized ld/st stream in
//!   swizzled peer order.

use crate::chunk::{Chunk, Region, TensorId, TensorTable};
use crate::error::{Error, Result};
use crate::schedule::templates::shard_region;
use crate::schedule::{CommOp, CommSchedule, Dep, TransferKind};
use crate::topo::Rank;

/// One transfer slot on a stream, as foreign runtimes describe it: source
/// and destination are explicit, ordering is the slot position.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamOp {
    pub src_rank: Rank,
    pub dst_rank: Rank,
    pub src: Chunk,
    pub dst: Chunk,
    pub reduce: bool,
}

/// A stream-level plan: per rank, an ordered list of streams, each an
/// ordered list of [`StreamOp`]s. Ops on one stream execute in slot order;
/// ops on different streams are unordered.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamPlan {
    pub world: usize,
    pub tensors: TensorTable,
    /// `streams[rank][stream][slot]`. Every op must involve `rank` as its
    /// source (push semantics) or destination (pull semantics).
    pub streams: Vec<Vec<Vec<StreamOp>>>,
}

/// Lift a stream-level plan into a chunk schedule: stream order becomes
/// explicit dependency chains, slot by slot.
pub fn lift(plan: &StreamPlan) -> Result<CommSchedule> {
    if plan.streams.len() != plan.world {
        return Err(Error::PlanIo(format!(
            "stream plan has {} rank entries for world {}",
            plan.streams.len(),
            plan.world
        )));
    }
    let mut sched = CommSchedule::new(plan.world, plan.tensors.clone());
    for (rank, streams) in plan.streams.iter().enumerate() {
        for (si, stream) in streams.iter().enumerate() {
            let mut prev: Option<Dep> = None;
            for (slot, op) in stream.iter().enumerate() {
                let kind = if op.src_rank == rank {
                    TransferKind::Push
                } else if op.dst_rank == rank {
                    TransferKind::Pull
                } else {
                    return Err(Error::PlanIo(format!(
                        "stream op [rank {rank}, stream {si}, slot {slot}] moves \
                         {} -> {} without involving its issuing rank",
                        op.src_rank, op.dst_rank
                    )));
                };
                let peer = if kind == TransferKind::Push { op.dst_rank } else { op.src_rank };
                let deps: Vec<Dep> = prev.into_iter().collect();
                let index = sched.add_op(
                    rank,
                    CommOp::P2p {
                        kind,
                        peer,
                        src: op.src.clone(),
                        dst: op.dst.clone(),
                        reduce: op.reduce,
                        deps,
                    },
                )?;
                prev = Some(Dep { rank, index });
            }
        }
    }
    Ok(sched)
}

/// Flux-style AllGather as a stream plan: rank `r` pulls shard `p` from
/// its owner in `pieces` tile-sized sub-chunks, on a dedicated stream per
/// peer (maximal over-decomposition, co-located loads).
pub fn flux_ag_stream(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
    pieces: usize,
) -> Result<StreamPlan> {
    if pieces == 0 {
        return Err(Error::PlanIo("flux importer: pieces must be >= 1".into()));
    }
    let shape = table.get(tensor)?.shape.clone();
    let mut streams: Vec<Vec<Vec<StreamOp>>> = Vec::with_capacity(world);
    for r in 0..world {
        let mut rank_streams = Vec::with_capacity(world - 1);
        for i in 1..world {
            let peer = (r + i) % world;
            let shard: Region = shard_region(&shape, axis, world, peer)?;
            let subs = shard.split(axis, pieces).map_err(|e| {
                Error::PlanIo(format!("flux importer: shard does not split: {e}"))
            })?;
            let stream: Vec<StreamOp> = subs
                .into_iter()
                .map(|piece| StreamOp {
                    src_rank: peer,
                    dst_rank: r,
                    src: Chunk::new(tensor, piece.clone()),
                    dst: Chunk::new(tensor, piece),
                    reduce: false,
                })
                .collect();
            rank_streams.push(stream);
        }
        streams.push(rank_streams);
    }
    Ok(StreamPlan { world, tensors: table.clone(), streams })
}

/// Triton-distributed-style AllGather as a stream plan: each rank's single
/// specialized ld/st stream pushes its own full shard to every peer in
/// swizzled order (fixed one-chunk-per-shard decomposition).
pub fn triton_dist_ag_stream(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
) -> Result<StreamPlan> {
    let shape = table.get(tensor)?.shape.clone();
    let mut streams: Vec<Vec<Vec<StreamOp>>> = Vec::with_capacity(world);
    for r in 0..world {
        let own = shard_region(&shape, axis, world, r)?;
        let stream: Vec<StreamOp> = (1..world)
            .map(|i| StreamOp {
                src_rank: r,
                dst_rank: (r + i) % world,
                src: Chunk::new(tensor, own.clone()),
                dst: Chunk::new(tensor, own.clone()),
                reduce: false,
            })
            .collect();
        streams.push(vec![stream]);
    }
    Ok(StreamPlan { world, tensors: table.clone(), streams })
}

/// Import a Flux-style AllGather straight to a validated [`CommSchedule`].
pub fn flux_ag(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
    pieces: usize,
) -> Result<CommSchedule> {
    let sched = lift(&flux_ag_stream(table, tensor, axis, world, pieces)?)?;
    crate::schedule::validate::validate(&sched)?;
    Ok(sched)
}

/// Import a Triton-distributed-style AllGather straight to a validated
/// [`CommSchedule`].
pub fn triton_dist_ag(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    world: usize,
) -> Result<CommSchedule> {
    let sched = lift(&triton_dist_ag_stream(table, tensor, axis, world)?)?;
    crate::schedule::validate::validate(&sched)?;
    Ok(sched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::DType;
    use crate::schedule::validate::validate;

    fn table(rows: usize) -> (TensorTable, TensorId) {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[rows, 16], DType::F32).unwrap();
        (t, x)
    }

    #[test]
    fn lift_chains_stream_order_only() {
        let (t, x) = table(8);
        let piece = |i: usize| Chunk::new(x, Region::rows(i * 2, 2, 16));
        let plan = StreamPlan {
            world: 2,
            tensors: t,
            streams: vec![
                vec![
                    // stream 0: two slots -> chained
                    vec![
                        StreamOp { src_rank: 0, dst_rank: 1, src: piece(0), dst: piece(0), reduce: false },
                        StreamOp { src_rank: 0, dst_rank: 1, src: piece(1), dst: piece(1), reduce: false },
                    ],
                    // stream 1: independent
                    vec![StreamOp { src_rank: 0, dst_rank: 1, src: piece(2), dst: piece(2), reduce: false }],
                ],
                vec![],
            ],
        };
        let s = lift(&plan).unwrap();
        assert_eq!(s.per_rank[0].len(), 3);
        assert!(s.per_rank[0][0].deps().is_empty());
        assert_eq!(s.per_rank[0][1].deps(), &[Dep::on(0, 0)]);
        assert!(s.per_rank[0][2].deps().is_empty(), "cross-stream ops stay unordered");
        validate(&s).unwrap();
    }

    #[test]
    fn lift_rejects_third_party_ops() {
        let (t, x) = table(8);
        let c = Chunk::new(x, Region::rows(0, 2, 16));
        let plan = StreamPlan {
            world: 3,
            tensors: t,
            streams: vec![
                vec![vec![StreamOp { src_rank: 1, dst_rank: 2, src: c.clone(), dst: c, reduce: false }]],
                vec![],
                vec![],
            ],
        };
        let e = lift(&plan).unwrap_err();
        assert!(e.to_string().contains("issuing rank"), "{e}");
    }

    #[test]
    fn flux_import_validates_all_worlds() {
        for world in [2usize, 4, 8] {
            let (t, x) = table(world * 4);
            let s = flux_ag(&t, x, 0, world, 2).unwrap();
            // per rank: (world-1) peers x 2 pieces, pulls only
            assert_eq!(s.per_rank[0].len(), (world - 1) * 2);
            assert!(s
                .per_rank
                .iter()
                .flatten()
                .all(|o| matches!(o, CommOp::P2p { kind: TransferKind::Pull, .. })));
            // per-peer chains: piece 1 of each peer stream depends on piece 0
            assert_eq!(s.per_rank[0][1].deps().len(), 1);
            assert!(s.per_rank[0][0].deps().is_empty());
        }
    }

    #[test]
    fn triton_dist_import_validates_all_worlds() {
        for world in [2usize, 4, 8] {
            let (t, x) = table(world * 2);
            let s = triton_dist_ag(&t, x, 0, world).unwrap();
            // one push per peer, all chained on the single stream
            assert_eq!(s.per_rank[0].len(), world - 1);
            for (i, op) in s.per_rank[0].iter().enumerate() {
                assert!(matches!(op, CommOp::P2p { kind: TransferKind::Push, .. }));
                assert_eq!(op.deps().len(), usize::from(i > 0));
            }
        }
    }

    #[test]
    fn imported_plans_split_like_templates() {
        let (t, x) = table(16);
        let s = triton_dist_ag(&t, x, 0, 4).unwrap();
        let s2 = s.split_p2p(0, 2).unwrap();
        validate(&s2).unwrap();
        assert_eq!(s2.num_ops(), s.num_ops() * 2);
        assert_eq!(s.total_link_bytes().unwrap(), s2.total_link_bytes().unwrap());
    }

    #[test]
    fn flux_pieces_must_divide() {
        let (t, x) = table(8); // shards of 2 rows don't split 3 ways
        assert!(flux_ag(&t, x, 0, 4, 3).is_err());
        assert!(flux_ag(&t, x, 0, 4, 0).is_err());
    }
}
