//! Canonical pretty-printer for the `.sched` format.
//!
//! The output is *the* canonical form: deterministic, byte-stable, and the
//! input to [`super::dsl::content_hash`]. The parser accepts a superset
//! (flexible whitespace, comments, `(r, i)` spacing), but printing any
//! parsed schedule reproduces this form bit-identically.

use crate::chunk::{Chunk, TensorTable};
use crate::error::{Error, Result};
use crate::schedule::{CommOp, CommSchedule, Dep, TransferKind};

use super::dsl::{collective_name, dtype_name, is_valid_tensor_name, FORMAT_VERSION};

/// Render a schedule in canonical `.sched` text.
///
/// Fails only when the schedule is not representable: a chunk referencing
/// a tensor id outside the table, or a tensor name the grammar cannot
/// express. Structural problems (bad deps, oob peers) print fine — `plan
/// lint` exists to reject those.
pub fn print_schedule(sched: &CommSchedule) -> Result<String> {
    let mut out = String::new();
    out.push_str(&format!("plan {FORMAT_VERSION} world {}\n", sched.world));
    for (_, decl) in sched.tensors.iter() {
        if !is_valid_tensor_name(&decl.name) {
            return Err(Error::PlanIo(format!(
                "tensor name `{}` is not representable in the DSL",
                decl.name
            )));
        }
        let dims: Vec<String> = decl.shape.iter().map(|d| d.to_string()).collect();
        out.push_str(&format!(
            "tensor {} {} {}\n",
            decl.name,
            dtype_name(decl.dtype),
            dims.join("x")
        ));
    }
    out.push('\n');
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        out.push_str(&format!("rank {rank}:\n"));
        for op in ops {
            out.push_str("  ");
            out.push_str(&op_line(op, &sched.tensors)?);
            out.push('\n');
        }
    }
    Ok(out)
}

/// One op in canonical line form (no indentation, no newline).
pub fn op_line(op: &CommOp, table: &TensorTable) -> Result<String> {
    let mut s = String::new();
    match op {
        CommOp::P2p { kind, peer, src, dst, reduce, deps } => {
            s.push_str(match kind {
                TransferKind::Push => "push ",
                TransferKind::Pull => "pull ",
            });
            s.push_str(&chunk_str(src, table)?);
            s.push_str(" -> ");
            s.push_str(&chunk_str(dst, table)?);
            s.push_str(&format!(" peer {peer}"));
            if *reduce {
                s.push_str(" reduce");
            }
            push_deps(&mut s, deps);
        }
        CommOp::LocalCopy { src, dst, deps } => {
            s.push_str("copy ");
            s.push_str(&chunk_str(src, table)?);
            s.push_str(" -> ");
            s.push_str(&chunk_str(dst, table)?);
            push_deps(&mut s, deps);
        }
        CommOp::Collective { kind, src, dst, ranks, deps } => {
            s.push_str(collective_name(*kind));
            s.push(' ');
            s.push_str(&chunk_str(src, table)?);
            s.push_str(" -> ");
            s.push_str(&chunk_str(dst, table)?);
            s.push_str(" ranks");
            for r in ranks {
                s.push_str(&format!(" {r}"));
            }
            push_deps(&mut s, deps);
        }
    }
    Ok(s)
}

fn chunk_str(c: &Chunk, table: &TensorTable) -> Result<String> {
    let decl = table
        .get(c.tensor)
        .map_err(|_| Error::PlanIo(format!("chunk references unknown tensor id {:?}", c.tensor)))?;
    if !is_valid_tensor_name(&decl.name) {
        return Err(Error::PlanIo(format!(
            "tensor name `{}` is not representable in the DSL",
            decl.name
        )));
    }
    let dims: Vec<String> = c
        .region
        .offset
        .iter()
        .zip(&c.region.sizes)
        .map(|(o, sz)| format!("{}:{}", o, o + sz))
        .collect();
    Ok(format!("{}[{}]", decl.name, dims.join(", ")))
}

fn push_deps(s: &mut String, deps: &[Dep]) {
    if deps.is_empty() {
        return;
    }
    s.push_str(" deps");
    for d in deps {
        s.push_str(&format!(" ({},{})", d.rank, d.index));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::{DType, Region, TensorId};
    use crate::plan_io::dsl::SchedBuilder;

    fn two_rank() -> CommSchedule {
        let mut b = SchedBuilder::new(2);
        let x = b.tensor("x", &[8, 16], DType::F32).unwrap();
        let d = b.push(0, 1, b.shard(x, 0, 0).unwrap(), &[]).unwrap();
        b.pull(1, 0, b.shard(x, 0, 1).unwrap(), &[d]).unwrap();
        b.build_unchecked()
    }

    #[test]
    fn canonical_text_shape() {
        let text = print_schedule(&two_rank()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "plan v1 world 2");
        assert_eq!(lines[1], "tensor x f32 8x16");
        assert_eq!(lines[2], "");
        assert_eq!(lines[3], "rank 0:");
        assert_eq!(lines[4], "  push x[0:4, 0:16] -> x[0:4, 0:16] peer 1");
        assert_eq!(lines[5], "rank 1:");
        assert_eq!(lines[6], "  pull x[4:8, 0:16] -> x[4:8, 0:16] peer 0 deps (0,0)");
        assert_eq!(lines.len(), 7);
    }

    #[test]
    fn reduce_copy_and_collective_lines() {
        let mut b = SchedBuilder::new(2);
        let x = b.tensor("x", &[8, 16], DType::BF16).unwrap();
        let c = b.shard(x, 0, 0).unwrap();
        b.push_reduce(0, 1, c.clone(), &[]).unwrap();
        b.copy(0, c.clone(), b.shard(x, 0, 1).unwrap(), &[Dep::on(0, 0)]).unwrap();
        b.collective(
            1,
            crate::schedule::CollectiveKind::AllReduce,
            c.clone(),
            c,
            &[0, 1],
            &[],
        )
        .unwrap();
        let text = print_schedule(&b.build_unchecked()).unwrap();
        assert!(text.contains("tensor x bf16 8x16"), "{text}");
        assert!(text.contains("push x[0:4, 0:16] -> x[0:4, 0:16] peer 1 reduce"), "{text}");
        assert!(text.contains("copy x[0:4, 0:16] -> x[4:8, 0:16] deps (0,0)"), "{text}");
        assert!(
            text.contains("allreduce x[0:4, 0:16] -> x[0:4, 0:16] ranks 0 1"),
            "{text}"
        );
    }

    #[test]
    fn unknown_tensor_id_unprintable() {
        let mut s = two_rank();
        s.per_rank[0].push(CommOp::LocalCopy {
            src: Chunk::new(TensorId(7), Region::rows(0, 1, 16)),
            dst: Chunk::new(TensorId(7), Region::rows(0, 1, 16)),
            deps: vec![],
        });
        assert!(print_schedule(&s).is_err());
    }
}
