//! Device mesh and interconnect topology.
//!
//! Models a (possibly multi-node) accelerator mesh: per-level link specs
//! (local / intra-node / inter-node, the hierarchy the heterogeneous
//! swizzled schedules of Fig. 4(e) pipeline across), device compute
//! parameters, and the per-backend capability/curve matrix ([`crate::hw::Arch`]).
//!
//! There are NO hardcoded machine constructors here: every [`Topology`] is
//! instantiated from a data-driven description — a built-in catalog entry
//! or a parsed `.topo` file — via [`crate::hw::catalog`] /
//! [`crate::hw::TopoDesc::instantiate`]. The paper's 8×H100 testbed
//! (NVLink/NVSwitch, 900 GB/s aggregate) is the catalog's `h100_node`
//! entry.

use crate::error::{Error, Result};
use crate::hw::Arch;

/// Rank index within the mesh.
pub type Rank = usize;

/// Hierarchy level of a link (Fig. 4e pipelines across levels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkLevel {
    /// Same device (local copy; effectively SOL bandwidth).
    Local,
    /// Intra-node NVLink/NVSwitch (or PCIe on archs without NVLink).
    IntraNode,
    /// Inter-node fabric (IB/RoCE).
    InterNode,
}

/// Point-to-point link characteristics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    pub level: LinkLevel,
    /// Peak unidirectional bandwidth, GB/s.
    pub bw_gbps: f64,
    /// Base propagation latency, microseconds.
    pub lat_us: f64,
}

/// A (possibly multi-node) device mesh with link specs between ranks.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub world: usize,
    pub ranks_per_node: usize,
    /// Same-device copies (SOL HBM bandwidth).
    pub local: LinkSpec,
    pub intra: LinkSpec,
    pub inter: LinkSpec,
    /// SMs per device (H100 SXM: 132).
    pub sms_per_device: usize,
    /// Copy engines per device usable for P2P (H100: ~3 usable DMA engines).
    pub copy_engines_per_device: usize,
    /// Per-SM dense f32-accumulate throughput, TFLOP/s (H100 bf16 tensor
    /// core ≈ 990 TFLOPS / 132 SMs ≈ 7.5).
    pub sm_tflops: f64,
    /// Whether the switch supports in-network reduction (NVLS/SHARP).
    pub switch_reduce: bool,
    /// Per-backend capability matrix + bandwidth curves for this machine
    /// generation (the queryable store sim/codegen/autotune read).
    pub arch: Arch,
}

impl Topology {
    /// Node index of a rank.
    pub fn node_of(&self, r: Rank) -> usize {
        r / self.ranks_per_node
    }

    /// Link spec between two ranks.
    pub fn link(&self, src: Rank, dst: Rank) -> Result<LinkSpec> {
        if src >= self.world || dst >= self.world {
            return Err(Error::Schedule(format!(
                "rank out of range: {src}->{dst} (world {})",
                self.world
            )));
        }
        if src == dst {
            return Ok(self.local);
        }
        if self.node_of(src) == self.node_of(dst) {
            Ok(self.intra)
        } else {
            Ok(self.inter)
        }
    }

    /// Ranks on the same node as `r` (Fig. 4e intra-level port group).
    pub fn node_peers(&self, r: Rank) -> Vec<Rank> {
        let n = self.node_of(r);
        (0..self.world).filter(|&x| self.node_of(x) == n && x != r).collect()
    }

    /// Device peak TFLOP/s (all SMs).
    pub fn device_tflops(&self) -> f64 {
        self.sm_tflops * self.sms_per_device as f64
    }

    /// Ring successor / predecessor (the canonical ring order of Fig. 4c).
    pub fn ring_next(&self, r: Rank) -> Rank {
        (r + 1) % self.world
    }
    pub fn ring_prev(&self, r: Rank) -> Rank {
        (r + self.world - 1) % self.world
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    #[test]
    fn single_node_links() {
        let t = catalog::topology("h100_node", 8).unwrap();
        assert_eq!(t.world, 8);
        let l = t.link(0, 5).unwrap();
        assert_eq!(l.level, LinkLevel::IntraNode);
        assert!(l.bw_gbps > 100.0);
        assert_eq!(t.link(3, 3).unwrap().level, LinkLevel::Local);
        assert_eq!(t.link(3, 3).unwrap(), t.local);
    }

    #[test]
    fn zero_world_rejected() {
        assert!(catalog::topology("h100_node", 0).is_err());
    }

    #[test]
    fn rank_bounds_checked() {
        let t = catalog::topology("h100_node", 4).unwrap();
        assert!(t.link(0, 4).is_err());
        assert!(t.link(9, 0).is_err());
    }

    #[test]
    fn multinode_levels() {
        let t = catalog::topology_nodes("h100_multinode", 2, 8).unwrap();
        assert_eq!(t.world, 8);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert_eq!(t.link(0, 3).unwrap().level, LinkLevel::IntraNode);
        assert_eq!(t.link(0, 4).unwrap().level, LinkLevel::InterNode);
        assert!(t.link(0, 4).unwrap().bw_gbps < t.link(0, 1).unwrap().bw_gbps);
    }

    #[test]
    fn node_peers() {
        let t = catalog::topology_nodes("h100_multinode", 2, 8).unwrap();
        assert_eq!(t.node_peers(1), vec![0, 2, 3]);
        assert_eq!(t.node_peers(5), vec![4, 6, 7]);
    }

    #[test]
    fn ring_order() {
        let t = catalog::topology("h100_node", 4).unwrap();
        assert_eq!(t.ring_next(3), 0);
        assert_eq!(t.ring_prev(0), 3);
        // ring_next and ring_prev are inverses
        for r in 0..4 {
            assert_eq!(t.ring_prev(t.ring_next(r)), r);
        }
    }

    #[test]
    fn device_tflops_scale() {
        let t = catalog::topology("h100_node", 8).unwrap();
        // H100 ballpark: ~990 TFLOPS
        assert!((t.device_tflops() - 990.0).abs() < 50.0);
    }
}
