//! Parser for the paper's `@sy.*` kernel annotations (Listing 1).
//!
//! Annotations are structured comments in the local kernel source — Python
//! comments with OpenMP-pragma-like directives. They expose three things
//! (§5.2): tile sizes, the tile index identifier, and the tile scheduler.
//! We parse the same directive grammar from our Pallas kernels (which is
//! what `python/compile/kernels/*.py` carries), so the Rust compiler's view
//! of the kernel's tile structure comes from the *actual* kernel source.
//!
//! Grammar (one directive per comment line; `#` or `//` prefix):
//! ```text
//! @sy.axis_count <AXIS> block=<IDENT|INT>
//! @sy.tile_id <persistent|grid>
//! @sy.dispatch begin | @sy.dispatch end
//! @sy.pid_map <AXIS>=<IDENT|INT> ...
//! ```
//! `block=<IDENT>` references a constant assignment (`BLOCK_M = 128`)
//! elsewhere in the same source, which we resolve.

use std::collections::HashMap;


use crate::error::{Error, Result};
use crate::kernel::grid::{Axis, TileGrid};

/// How the kernel advances its tile index (Listing 1's scheduler structure).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileIdKind {
    /// Persistent kernel: `tile_id += NUM_SMS` loop (Triton streamed GEMM).
    Persistent,
    /// One tile per grid step (Pallas grid).
    Grid,
}

/// Block size reference: literal or named constant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BlockRef {
    Lit(usize),
    Ident(String),
}

/// Parsed kernel annotations.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelAnnotations {
    /// Axis name -> block reference, in declaration order.
    pub axes: Vec<(String, BlockRef)>,
    pub tile_id: TileIdKind,
    /// Axis name -> pid variable (or literal grid dim index).
    pub pid_map: Vec<(String, String)>,
    /// Constants found in the source (`BLOCK_M = 128`).
    pub constants: HashMap<String, usize>,
    /// Whether a dispatch region was delimited.
    pub has_dispatch_region: bool,
}

impl KernelAnnotations {
    /// Resolve a block reference against source constants / overrides.
    pub fn resolve_block(&self, b: &BlockRef, overrides: &HashMap<String, usize>) -> Result<usize> {
        match b {
            BlockRef::Lit(v) => Ok(*v),
            BlockRef::Ident(name) => overrides
                .get(name)
                .or_else(|| self.constants.get(name))
                .copied()
                .ok_or_else(|| {
                    Error::Kernel(format!("unresolved block constant `{name}`"))
                }),
        }
    }

    /// Build a [`TileGrid`] by pairing annotated axes with problem sizes.
    ///
    /// `sizes` maps axis name -> problem size; `overrides` can re-bind block
    /// constants (the autotuner's tile-shape knob).
    pub fn to_grid(
        &self,
        sizes: &HashMap<String, usize>,
        overrides: &HashMap<String, usize>,
    ) -> Result<TileGrid> {
        let mut axes = Vec::with_capacity(self.axes.len());
        for (name, bref) in &self.axes {
            let size = *sizes.get(name).ok_or_else(|| {
                Error::Kernel(format!("no problem size given for axis `{name}`"))
            })?;
            let block = self.resolve_block(bref, overrides)?;
            axes.push(Axis::new(name, size, block)?);
        }
        TileGrid::new(axes)
    }
}

/// Parse annotations out of kernel source text.
pub fn parse_annotations(source: &str) -> Result<KernelAnnotations> {
    let mut axes = Vec::new();
    let mut tile_id = None;
    let mut pid_map = Vec::new();
    let mut constants = HashMap::new();
    let mut dispatch_depth = 0i32;
    let mut saw_dispatch = false;

    for (lineno, raw) in source.lines().enumerate() {
        let line = raw.trim();
        // constants: NAME = <int>  (module-level or in-kernel)
        if let Some((lhs, rhs)) = line.split_once('=') {
            let name = lhs.trim();
            let val = rhs.trim();
            if !name.is_empty()
                && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
                && name.chars().next().is_some_and(|c| c.is_ascii_uppercase())
            {
                if let Ok(v) = val.parse::<usize>() {
                    constants.insert(name.to_string(), v);
                }
            }
        }
        // directives live in comments
        let Some(at) = line.find("@sy.") else { continue };
        let directive = &line[at + 4..];
        let mut parts = directive.split_whitespace();
        let head = parts.next().unwrap_or("");
        let err = |m: &str| Error::Kernel(format!("line {}: {m}", lineno + 1));
        match head {
            "axis_count" => {
                let axis = parts
                    .next()
                    .ok_or_else(|| err("axis_count needs an axis name"))?;
                let blk = parts
                    .next()
                    .and_then(|t| t.strip_prefix("block="))
                    .ok_or_else(|| err("axis_count needs block=<ref>"))?;
                let bref = match blk.parse::<usize>() {
                    Ok(v) => BlockRef::Lit(v),
                    Err(_) => BlockRef::Ident(blk.to_string()),
                };
                if axes.iter().any(|(a, _): &(String, _)| a == axis) {
                    return Err(err(&format!("duplicate axis `{axis}`")));
                }
                axes.push((axis.to_string(), bref));
            }
            "tile_id" => {
                let kind = match parts.next() {
                    Some("persistent") => TileIdKind::Persistent,
                    Some("grid") => TileIdKind::Grid,
                    other => {
                        return Err(err(&format!(
                            "tile_id must be persistent|grid, got {other:?}"
                        )))
                    }
                };
                if tile_id.is_some() {
                    return Err(err("duplicate tile_id directive"));
                }
                tile_id = Some(kind);
            }
            "dispatch" => match parts.next() {
                Some("begin") => {
                    dispatch_depth += 1;
                    saw_dispatch = true;
                }
                Some("end") => {
                    dispatch_depth -= 1;
                    if dispatch_depth < 0 {
                        return Err(err("dispatch end without begin"));
                    }
                }
                other => return Err(err(&format!("dispatch must be begin|end, got {other:?}"))),
            },
            "pid_map" => {
                for kv in parts {
                    let (axis, var) = kv
                        .split_once('=')
                        .ok_or_else(|| err(&format!("bad pid_map entry `{kv}`")))?;
                    pid_map.push((axis.to_string(), var.to_string()));
                }
            }
            other => {
                // Only flag identifiers as unknown directives; prose that
                // merely mentions "@sy.*" (docstrings) is skipped.
                if !other.is_empty()
                    && other.chars().all(|c| c.is_ascii_lowercase() || c == '_')
                {
                    return Err(Error::Kernel(format!(
                        "line {}: unknown directive @sy.{other}",
                        lineno + 1
                    )));
                }
            }
        }
    }
    if dispatch_depth != 0 {
        return Err(Error::Kernel("unbalanced @sy.dispatch begin/end".into()));
    }
    if axes.is_empty() {
        return Err(Error::Kernel("no @sy.axis_count directives found".into()));
    }
    // every pid_map axis must be declared
    for (a, _) in &pid_map {
        if !axes.iter().any(|(n, _)| n == a) {
            return Err(Error::Kernel(format!("pid_map references unknown axis `{a}`")));
        }
    }
    Ok(KernelAnnotations {
        axes,
        tile_id: tile_id.unwrap_or(TileIdKind::Grid),
        pid_map,
        constants,
        has_dispatch_region: saw_dispatch,
    })
}

/// Parse annotations from a kernel source file on disk.
pub fn parse_annotations_file(path: &std::path::Path) -> Result<KernelAnnotations> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| Error::Kernel(format!("read {}: {e}", path.display())))?;
    parse_annotations(&src)
}

#[cfg(test)]
mod tests {
    use super::*;

    const LISTING1: &str = r#"
BLOCK_SIZE_M = 128
BLOCK_SIZE_N = 256

@triton.jit
def kernel_gemm(a_ptr, b_ptr):
    start_pid = tl.program_id(axis=0)
    # @sy.axis_count M block=BLOCK_SIZE_M
    num_pid_m = tl.cdiv(M, BLOCK_SIZE_M)
    # @sy.axis_count N block=BLOCK_SIZE_N
    # @sy.tile_id persistent
    tile_id = start_pid - NUM_SMS
    # @sy.dispatch begin
    # @sy.pid_map M=pid_m N=pid_n
    pid_m, pid_n = get_pid_mn(tile_id)
    # @sy.dispatch end
"#;

    #[test]
    fn parses_listing1_style() {
        let a = parse_annotations(LISTING1).unwrap();
        assert_eq!(a.axes.len(), 2);
        assert_eq!(a.axes[0], ("M".into(), BlockRef::Ident("BLOCK_SIZE_M".into())));
        assert_eq!(a.tile_id, TileIdKind::Persistent);
        assert!(a.has_dispatch_region);
        assert_eq!(a.pid_map, vec![("M".into(), "pid_m".into()), ("N".into(), "pid_n".into())]);
        assert_eq!(a.constants["BLOCK_SIZE_M"], 128);
    }

    #[test]
    fn to_grid_resolves_constants_and_overrides() {
        let a = parse_annotations(LISTING1).unwrap();
        let sizes: HashMap<String, usize> =
            [("M".to_string(), 1024), ("N".to_string(), 512)].into();
        let g = a.to_grid(&sizes, &HashMap::new()).unwrap();
        assert_eq!(g.axes[0].block, 128);
        assert_eq!(g.axes[1].block, 256);
        assert_eq!(g.num_tiles(), 8 * 2);
        // autotuner override wins
        let ov: HashMap<String, usize> = [("BLOCK_SIZE_M".to_string(), 64)].into();
        let g2 = a.to_grid(&sizes, &ov).unwrap();
        assert_eq!(g2.axes[0].block, 64);
    }

    #[test]
    fn missing_size_errors() {
        let a = parse_annotations(LISTING1).unwrap();
        let sizes: HashMap<String, usize> = [("M".to_string(), 1024)].into();
        assert!(a.to_grid(&sizes, &HashMap::new()).is_err());
    }

    #[test]
    fn literal_block() {
        let a = parse_annotations("# @sy.axis_count Q block=64\n").unwrap();
        assert_eq!(a.axes[0].1, BlockRef::Lit(64));
        assert_eq!(a.tile_id, TileIdKind::Grid); // default
    }

    #[test]
    fn unresolved_constant_errors() {
        let a = parse_annotations("# @sy.axis_count M block=NOPE\n").unwrap();
        let sizes: HashMap<String, usize> = [("M".to_string(), 64)].into();
        let e = a.to_grid(&sizes, &HashMap::new()).unwrap_err();
        assert!(e.to_string().contains("NOPE"));
    }

    #[test]
    fn error_cases() {
        assert!(parse_annotations("x = 1\n").is_err()); // no axes
        assert!(parse_annotations("# @sy.axis_count M\n").is_err()); // no block
        assert!(parse_annotations("# @sy.tile_id bogus\n# @sy.axis_count M block=8\n").is_err());
        assert!(parse_annotations("# @sy.dispatch end\n# @sy.axis_count M block=8\n").is_err());
        assert!(parse_annotations("# @sy.dispatch begin\n# @sy.axis_count M block=8\n").is_err());
        assert!(parse_annotations("# @sy.bogus\n# @sy.axis_count M block=8\n").is_err());
        assert!(parse_annotations(
            "# @sy.axis_count M block=8\n# @sy.axis_count M block=8\n"
        )
        .is_err()); // duplicate axis
        assert!(parse_annotations(
            "# @sy.axis_count M block=8\n# @sy.pid_map Z=pid_z\n"
        )
        .is_err()); // unknown pid_map axis
    }

    #[test]
    fn parses_real_pallas_gemm_source() {
        // The shipped Pallas kernel carries the same directives; parsing it
        // ties the Rust compiler's view to the real L1 source.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("python/compile/kernels/gemm.py");
        if !path.exists() {
            return; // layout changed; covered by integration tests
        }
        let a = parse_annotations_file(&path).unwrap();
        let names: Vec<&str> = a.axes.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["M", "N", "K"]);
        assert_eq!(a.constants["BLOCK_M"], 128);
    }
}
