//! Tile schedulers and the scheduler swizzle (paper §5.2, Fig. 6).
//!
//! A [`TileScheduler`] is a visiting order — a permutation of the grid's
//! tiles. Prior systems reconcile the communication layout with the compute
//! layout by physically reordering data (Fig. 6b); Syncopate instead
//! *swizzles the scheduler*: waves are reordered so each chunk is consumed
//! as soon as it arrives, with an intra-chunk order that preserves locality
//! (Fig. 6c).

use std::collections::HashMap;


use crate::error::{Error, Result};
use crate::kernel::grid::{TileGrid, TileId};

/// Order in which tiles *within* one chunk group are visited.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IntraOrder {
    /// Plain row-major within the group.
    RowMajor,
    /// Boustrophedon (snake) order: alternate direction every row — adjacent
    /// tiles share an operand block, preserving cache/VMEM locality.
    Snake,
    /// Group columns in pairs before advancing rows (L2-friendly for GEMM B).
    GroupedCols { group: usize },
}

/// Top-level swizzle policy — one of the autotuner's intra-chunk knobs.
#[derive(Debug, Clone, PartialEq)]
pub enum SwizzlePolicy {
    /// The kernel's native order (whatever the local kernel did).
    RowMajor,
    /// Column-major traversal.
    ColMajor,
    /// Follow chunk arrival order; `intra` orders tiles inside each chunk.
    ChunkMajor { intra: IntraOrder },
}

/// A concrete visiting order over a grid's tiles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileScheduler {
    pub order: Vec<TileId>,
}

impl TileScheduler {
    /// Native row-major order.
    pub fn row_major(grid: &TileGrid) -> Self {
        TileScheduler { order: (0..grid.num_tiles()).collect() }
    }

    /// Column-major order (last axis outermost) for 2-D grids; falls back to
    /// row-major otherwise.
    pub fn col_major(grid: &TileGrid) -> Self {
        if grid.rank() != 2 {
            return Self::row_major(grid);
        }
        let counts = grid.tile_counts();
        let mut order = Vec::with_capacity(grid.num_tiles());
        for j in 0..counts[1] {
            for i in 0..counts[0] {
                order.push(grid.linear(&[i, j]).expect("in range"));
            }
        }
        TileScheduler { order }
    }

    /// Chunk-major swizzle: visit chunk groups in `arrival` order, applying
    /// `intra` within each group. Tiles not covered by any group (pure-local
    /// tiles) are scheduled FIRST — they need no communication and fill the
    /// pipeline while the first chunk is in flight.
    ///
    /// `groups` maps group key -> tiles; `arrival` is the ordered list of
    /// group keys. Every tile must appear in at most one group.
    pub fn chunk_major(
        grid: &TileGrid,
        groups: &HashMap<usize, Vec<TileId>>,
        arrival: &[usize],
        intra: IntraOrder,
    ) -> Result<Self> {
        let n = grid.num_tiles();
        let mut seen = vec![false; n];
        let mut order = Vec::with_capacity(n);
        // membership check + duplicate detection
        for (k, tiles) in groups {
            for &t in tiles {
                if t >= n {
                    return Err(Error::Kernel(format!("group {k}: tile {t} out of range")));
                }
            }
        }
        let mut grouped = vec![false; n];
        for tiles in groups.values() {
            for &t in tiles {
                if grouped[t] {
                    return Err(Error::Kernel(format!("tile {t} in multiple chunk groups")));
                }
                grouped[t] = true;
            }
        }
        // local tiles first
        for t in 0..n {
            if !grouped[t] {
                order.push(t);
                seen[t] = true;
            }
        }
        // then chunks in arrival order
        for k in arrival {
            let Some(tiles) = groups.get(k) else {
                return Err(Error::Kernel(format!("arrival references unknown group {k}")));
            };
            let mut tiles = tiles.clone();
            apply_intra(grid, &mut tiles, intra)?;
            for t in tiles {
                if seen[t] {
                    return Err(Error::Kernel(format!("tile {t} scheduled twice")));
                }
                seen[t] = true;
                order.push(t);
            }
        }
        if order.len() != n {
            return Err(Error::Kernel(format!(
                "swizzle covers {}/{} tiles (arrival list missing groups?)",
                order.len(),
                n
            )));
        }
        Ok(TileScheduler { order })
    }

    /// Build from a policy (ChunkMajor requires groups + arrival).
    pub fn from_policy(
        grid: &TileGrid,
        policy: &SwizzlePolicy,
        groups: Option<(&HashMap<usize, Vec<TileId>>, &[usize])>,
    ) -> Result<Self> {
        match policy {
            SwizzlePolicy::RowMajor => Ok(Self::row_major(grid)),
            SwizzlePolicy::ColMajor => Ok(Self::col_major(grid)),
            SwizzlePolicy::ChunkMajor { intra } => {
                let (g, a) = groups.ok_or_else(|| {
                    Error::Kernel("ChunkMajor policy needs chunk groups".into())
                })?;
                Self::chunk_major(grid, g, a, *intra)
            }
        }
    }

    /// Is this a valid permutation of `n` tiles? (Swizzle invariant: the
    /// transformation never drops or duplicates work.)
    pub fn is_permutation(&self, n: usize) -> bool {
        if self.order.len() != n {
            return false;
        }
        let mut seen = vec![false; n];
        for &t in &self.order {
            if t >= n || seen[t] {
                return false;
            }
            seen[t] = true;
        }
        true
    }

    /// Position of each tile in the visiting order (inverse permutation).
    ///
    /// Orders reaching this point are usually compiler-built permutations,
    /// but hand-edited or imported plans can carry anything — a
    /// non-permutation order (out-of-range or duplicated tile) is a
    /// [`Error::Kernel`] here, not an index panic.
    pub fn positions(&self) -> Result<Vec<usize>> {
        let n = self.order.len();
        let mut pos = vec![usize::MAX; n];
        for (p, &t) in self.order.iter().enumerate() {
            if t >= n {
                return Err(Error::Kernel(format!(
                    "tile order is not a permutation: tile {t} out of range {n}"
                )));
            }
            if pos[t] != usize::MAX {
                return Err(Error::Kernel(format!(
                    "tile order is not a permutation: tile {t} visited twice"
                )));
            }
            pos[t] = p;
        }
        Ok(pos)
    }

    /// Locality score: mean #shared axis coordinates between consecutive
    /// tiles (higher = better operand reuse). Used by Fig. 11(d). Fails on
    /// orders referencing tiles outside the grid instead of panicking.
    pub fn locality_score(&self, grid: &TileGrid) -> Result<f64> {
        if self.order.len() < 2 {
            return Ok(1.0);
        }
        let mut shared = 0usize;
        for w in self.order.windows(2) {
            let a = grid.coords(w[0])?;
            let b = grid.coords(w[1])?;
            shared += a.iter().zip(&b).filter(|(x, y)| x == y).count();
        }
        Ok(shared as f64 / ((self.order.len() - 1) as f64 * grid.rank() as f64))
    }
}

fn apply_intra(grid: &TileGrid, tiles: &mut [TileId], intra: IntraOrder) -> Result<()> {
    match intra {
        IntraOrder::RowMajor => {
            tiles.sort_unstable();
            Ok(())
        }
        IntraOrder::Snake => {
            if grid.rank() < 2 {
                tiles.sort_unstable();
                return Ok(());
            }
            // sort by (row, col or reversed col on odd rows)
            let mut keyed: Vec<(Vec<usize>, TileId)> = tiles
                .iter()
                .map(|&t| (grid.coords(t).unwrap(), t))
                .collect();
            let ncols = grid.tile_counts()[1];
            keyed.sort_by_key(|(c, _)| {
                let col = if c[0] % 2 == 0 { c[1] } else { ncols - 1 - c[1] };
                (c[0], col)
            });
            for (i, (_, t)) in keyed.into_iter().enumerate() {
                tiles[i] = t;
            }
            Ok(())
        }
        IntraOrder::GroupedCols { group } => {
            if group == 0 {
                return Err(Error::Kernel("GroupedCols group must be > 0".into()));
            }
            let mut keyed: Vec<(Vec<usize>, TileId)> = tiles
                .iter()
                .map(|&t| (grid.coords(t).unwrap(), t))
                .collect();
            keyed.sort_by_key(|(c, _)| {
                let col_group = if c.len() > 1 { c[1] / group } else { 0 };
                (col_group, c[0], c.get(1).copied().unwrap_or(0))
            });
            for (i, (_, t)) in keyed.into_iter().enumerate() {
                tiles[i] = t;
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> TileGrid {
        TileGrid::gemm(256, 192, 64, 64).unwrap() // 4 x 3 tiles
    }

    #[test]
    fn row_major_is_identity_permutation() {
        let g = grid();
        let s = TileScheduler::row_major(&g);
        assert!(s.is_permutation(g.num_tiles()));
        assert_eq!(s.order, (0..12).collect::<Vec<_>>());
        assert!((s.locality_score(&g).unwrap() - 0.5).abs() < 0.2);
    }

    #[test]
    fn col_major_transposes() {
        let g = grid();
        let s = TileScheduler::col_major(&g);
        assert!(s.is_permutation(g.num_tiles()));
        // first column of tiles first: ids 0, 3, 6, 9
        assert_eq!(&s.order[..4], &[0, 3, 6, 9]);
    }

    #[test]
    fn chunk_major_orders_by_arrival_locals_first() {
        let g = grid();
        // chunks over M tiles: group k covers M-tile-row k (3 tiles each);
        // row 0 is local (no group), rows 1..3 arrive in order 3, 1, 2.
        let mut groups = HashMap::new();
        for k in 1..4usize {
            groups.insert(k, vec![k * 3, k * 3 + 1, k * 3 + 2]);
        }
        let arrival = vec![3, 1, 2];
        let s = TileScheduler::chunk_major(&g, &groups, &arrival, IntraOrder::RowMajor).unwrap();
        assert!(s.is_permutation(12));
        assert_eq!(&s.order[..3], &[0, 1, 2]); // local row first
        assert_eq!(&s.order[3..6], &[9, 10, 11]); // chunk 3 next
        assert_eq!(&s.order[6..9], &[3, 4, 5]);
        assert_eq!(&s.order[9..], &[6, 7, 8]);
    }

    #[test]
    fn chunk_major_snake_reverses_odd_rows() {
        let g = grid();
        let mut groups = HashMap::new();
        groups.insert(0usize, (0..12).collect::<Vec<_>>());
        let s =
            TileScheduler::chunk_major(&g, &groups, &[0], IntraOrder::Snake).unwrap();
        assert!(s.is_permutation(12));
        // row 0 forward (0,1,2), row 1 backward (5,4,3)
        assert_eq!(&s.order[..6], &[0, 1, 2, 5, 4, 3]);
        // snake beats row-major on locality
        let rm = TileScheduler::row_major(&g);
        assert!(s.locality_score(&g).unwrap() >= rm.locality_score(&g).unwrap());
    }

    #[test]
    fn chunk_major_error_cases() {
        let g = grid();
        let mut groups = HashMap::new();
        groups.insert(0usize, vec![0, 1]);
        // arrival references unknown group
        assert!(
            TileScheduler::chunk_major(&g, &groups, &[1], IntraOrder::RowMajor).is_err()
        );
        // missing groups -> incomplete cover
        assert!(
            TileScheduler::chunk_major(&g, &groups, &[], IntraOrder::RowMajor).is_err()
        );
        // duplicate tile across groups
        groups.insert(1usize, vec![1, 2]);
        assert!(
            TileScheduler::chunk_major(&g, &groups, &[0, 1], IntraOrder::RowMajor).is_err()
        );
        // tile out of range
        let mut g2 = HashMap::new();
        g2.insert(0usize, vec![99]);
        assert!(TileScheduler::chunk_major(&g, &g2, &[0], IntraOrder::RowMajor).is_err());
    }

    #[test]
    fn from_policy_dispatch() {
        let g = grid();
        assert_eq!(
            TileScheduler::from_policy(&g, &SwizzlePolicy::RowMajor, None).unwrap(),
            TileScheduler::row_major(&g)
        );
        assert!(TileScheduler::from_policy(
            &g,
            &SwizzlePolicy::ChunkMajor { intra: IntraOrder::RowMajor },
            None
        )
        .is_err());
    }

    #[test]
    fn grouped_cols_intra() {
        let g = grid();
        let mut groups = HashMap::new();
        groups.insert(0usize, (0..12).collect::<Vec<_>>());
        let s = TileScheduler::chunk_major(
            &g,
            &groups,
            &[0],
            IntraOrder::GroupedCols { group: 2 },
        )
        .unwrap();
        assert!(s.is_permutation(12));
        // first 8 tiles stay within column group {0,1}
        for &t in &s.order[..8] {
            assert!(g.coords(t).unwrap()[1] < 2);
        }
        // group = 0 rejected
        assert!(TileScheduler::chunk_major(
            &g,
            &groups,
            &[0],
            IntraOrder::GroupedCols { group: 0 }
        )
        .is_err());
    }

    #[test]
    fn permutation_detects_corruption() {
        let s = TileScheduler { order: vec![0, 1, 1] };
        assert!(!s.is_permutation(3));
        let s2 = TileScheduler { order: vec![0, 1] };
        assert!(!s2.is_permutation(3));
        let s3 = TileScheduler { order: vec![0, 1, 5] };
        assert!(!s3.is_permutation(3));
    }

    #[test]
    fn positions_inverse() {
        let s = TileScheduler { order: vec![2, 0, 1] };
        assert_eq!(s.positions().unwrap(), vec![1, 2, 0]);
    }

    #[test]
    fn malformed_orders_error_instead_of_panicking() {
        // regression (ISSUE 3): a hand-edited or imported plan may carry a
        // non-permutation order; these used to index-panic
        let dup = TileScheduler { order: vec![0, 2, 2] };
        let e = dup.positions().unwrap_err();
        assert!(e.to_string().contains("visited twice"), "{e}");
        let oob = TileScheduler { order: vec![0, 1, 7] };
        let e = oob.positions().unwrap_err();
        assert!(e.to_string().contains("out of range"), "{e}");
        // locality_score rejects tiles outside the grid
        let g = grid();
        let bad = TileScheduler { order: vec![0, 99] };
        assert!(bad.locality_score(&g).is_err());
    }
}
