//! Tile grids: the iteration space of an annotated local kernel.
//!
//! A [`TileGrid`] is the cross product of named axes, each covered by
//! fixed-size blocks — the Pallas/Triton grid. Tiles are identified by a
//! linear [`TileId`] in row-major axis order; Syncopate's scheduler swizzle
//! permutes the order in which they are *visited*, never the grid itself.


use crate::error::{Error, Result};
use crate::util::ceil_div;

/// Linear tile index within a grid (row-major over axes).
pub type TileId = usize;

/// One grid axis: a named problem dimension covered by `block`-sized tiles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Axis {
    pub name: String,
    /// Problem size along this axis (elements).
    pub size: usize,
    /// Tile (block) size along this axis (elements).
    pub block: usize,
}

impl Axis {
    pub fn new(name: &str, size: usize, block: usize) -> Result<Self> {
        if size == 0 || block == 0 {
            return Err(Error::Kernel(format!(
                "axis `{name}`: size and block must be > 0 (got {size}, {block})"
            )));
        }
        Ok(Axis { name: name.into(), size, block })
    }

    /// Number of tiles along this axis.
    pub fn tiles(&self) -> usize {
        ceil_div(self.size, self.block)
    }
}

/// The full tile iteration space.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileGrid {
    pub axes: Vec<Axis>,
}

impl TileGrid {
    pub fn new(axes: Vec<Axis>) -> Result<Self> {
        if axes.is_empty() {
            return Err(Error::Kernel("grid needs at least one axis".into()));
        }
        let mut names: Vec<&str> = axes.iter().map(|a| a.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != axes.len() {
            return Err(Error::Kernel("duplicate axis names".into()));
        }
        Ok(TileGrid { axes })
    }

    /// Convenience 2-D GEMM-style grid.
    pub fn gemm(m: usize, n: usize, block_m: usize, block_n: usize) -> Result<Self> {
        TileGrid::new(vec![Axis::new("M", m, block_m)?, Axis::new("N", n, block_n)?])
    }

    pub fn rank(&self) -> usize {
        self.axes.len()
    }

    pub fn axis(&self, name: &str) -> Option<(usize, &Axis)> {
        self.axes.iter().enumerate().find(|(_, a)| a.name == name)
    }

    /// Total tile count.
    pub fn num_tiles(&self) -> usize {
        self.axes.iter().map(|a| a.tiles()).product()
    }

    /// Per-axis tile counts.
    pub fn tile_counts(&self) -> Vec<usize> {
        self.axes.iter().map(|a| a.tiles()).collect()
    }

    /// Tile coordinates of a linear id (row-major). Hot path: no
    /// intermediate `tile_counts` allocation (perf pass, EXPERIMENTS §Perf).
    pub fn coords(&self, id: TileId) -> Result<Vec<usize>> {
        if id >= self.num_tiles() {
            return Err(Error::Kernel(format!(
                "tile id {id} out of {} tiles",
                self.num_tiles()
            )));
        }
        let mut rem = id;
        let mut c = vec![0usize; self.axes.len()];
        for d in (0..self.axes.len()).rev() {
            let n = self.axes[d].tiles();
            c[d] = rem % n;
            rem /= n;
        }
        Ok(c)
    }

    /// Linear id from tile coordinates (row-major).
    pub fn linear(&self, coords: &[usize]) -> Result<TileId> {
        if coords.len() != self.rank() {
            return Err(Error::Kernel("coordinate rank mismatch".into()));
        }
        let mut id = 0usize;
        for (d, &c) in coords.iter().enumerate() {
            let n = self.axes[d].tiles();
            if c >= n {
                return Err(Error::Kernel(format!(
                    "coord {c} out of {n} tiles on axis {}",
                    self.axes[d].name
                )));
            }
            id = id * n + c;
        }
        Ok(id)
    }

    /// Element range `[start, end)` covered by tile coordinate `c` on axis `d`
    /// (the last tile may be partial).
    pub fn axis_span(&self, d: usize, c: usize) -> (usize, usize) {
        let a = &self.axes[d];
        let start = c * a.block;
        (start, (start + a.block).min(a.size))
    }

    /// All tiles whose element footprint intersects the per-axis ranges
    /// `[(start, end)); one entry per axis, `None` = full axis.
    ///
    /// This is the chunk→tiles containment query of §5.2: a chunk's region,
    /// expressed in grid-axis element coordinates, selects the tiles that
    /// consume or produce it.
    pub fn tiles_intersecting(&self, ranges: &[Option<(usize, usize)>]) -> Result<Vec<TileId>> {
        if ranges.len() != self.rank() {
            return Err(Error::Kernel("range rank mismatch".into()));
        }
        // per-axis tile index ranges
        let mut spans: Vec<(usize, usize)> = Vec::with_capacity(self.rank());
        for (d, r) in ranges.iter().enumerate() {
            let a = &self.axes[d];
            match r {
                None => spans.push((0, a.tiles())),
                Some((s, e)) => {
                    if s >= e || *e > a.size {
                        return Err(Error::Kernel(format!(
                            "bad range [{s},{e}) on axis `{}` size {}",
                            a.name, a.size
                        )));
                    }
                    spans.push((s / a.block, ceil_div(*e, a.block)));
                }
            }
        }
        // cross product in row-major order; linear ids computed via
        // precomputed strides instead of per-tile `linear()` calls (hot in
        // the compile profile — perf pass, EXPERIMENTS §Perf)
        let mut strides = vec![1usize; self.rank()];
        for d in (0..self.rank().saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * self.axes[d + 1].tiles();
        }
        let count: usize = spans.iter().map(|(s, e)| e - s).product();
        let mut out = Vec::with_capacity(count);
        let mut c: Vec<usize> = spans.iter().map(|(s, _)| *s).collect();
        let mut id: usize = c.iter().zip(&strides).map(|(x, s)| x * s).sum();
        loop {
            out.push(id);
            let mut d = self.rank();
            loop {
                if d == 0 {
                    return Ok(out);
                }
                d -= 1;
                c[d] += 1;
                id += strides[d];
                if c[d] < spans[d].1 {
                    break;
                }
                id -= (c[d] - spans[d].0) * strides[d];
                c[d] = spans[d].0;
            }
        }
    }

    /// FLOPs of one tile of a GEMM grid with contraction depth `k` —
    /// 2·bm·bn·k, accounting for partial edge tiles at coordinates `c`.
    pub fn gemm_tile_flops(&self, id: TileId, k: usize) -> Result<f64> {
        let c = self.coords(id)?;
        let (m0, m1) = self.axis_span(0, c[0]);
        let (n0, n1) = self.axis_span(1, c[1]);
        Ok(2.0 * (m1 - m0) as f64 * (n1 - n0) as f64 * k as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> TileGrid {
        TileGrid::gemm(256, 128, 64, 64).unwrap() // 4 x 2 tiles
    }

    #[test]
    fn axis_tiles() {
        assert_eq!(Axis::new("M", 256, 64).unwrap().tiles(), 4);
        assert_eq!(Axis::new("M", 100, 64).unwrap().tiles(), 2); // partial last
        assert!(Axis::new("M", 0, 64).is_err());
        assert!(Axis::new("M", 64, 0).is_err());
    }

    #[test]
    fn grid_construction_checks() {
        assert!(TileGrid::new(vec![]).is_err());
        let dup = TileGrid::new(vec![
            Axis::new("M", 8, 2).unwrap(),
            Axis::new("M", 8, 2).unwrap(),
        ]);
        assert!(dup.is_err());
    }

    #[test]
    fn coords_linear_roundtrip() {
        let g = grid();
        assert_eq!(g.num_tiles(), 8);
        for id in 0..g.num_tiles() {
            let c = g.coords(id).unwrap();
            assert_eq!(g.linear(&c).unwrap(), id);
        }
        assert_eq!(g.coords(0).unwrap(), vec![0, 0]);
        assert_eq!(g.coords(1).unwrap(), vec![0, 1]);
        assert_eq!(g.coords(2).unwrap(), vec![1, 0]);
        assert!(g.coords(8).is_err());
        assert!(g.linear(&[4, 0]).is_err());
        assert!(g.linear(&[0]).is_err());
    }

    #[test]
    fn axis_span_partial_tail() {
        let g = TileGrid::gemm(100, 64, 64, 64).unwrap();
        assert_eq!(g.axis_span(0, 0), (0, 64));
        assert_eq!(g.axis_span(0, 1), (64, 100)); // partial
    }

    #[test]
    fn tiles_intersecting_rows() {
        let g = grid(); // M: 4 tiles of 64, N: 2 tiles of 64
        // rows [64, 192) -> M tiles 1,2; all N
        let t = g.tiles_intersecting(&[Some((64, 192)), None]).unwrap();
        assert_eq!(t, vec![2, 3, 4, 5]);
        // unaligned range [32, 96) spans M tiles 0 and 1
        let t2 = g.tiles_intersecting(&[Some((32, 96)), None]).unwrap();
        assert_eq!(t2, vec![0, 1, 2, 3]);
    }

    #[test]
    fn tiles_intersecting_full() {
        let g = grid();
        let all = g.tiles_intersecting(&[None, None]).unwrap();
        assert_eq!(all.len(), g.num_tiles());
    }

    #[test]
    fn tiles_intersecting_bad_range() {
        let g = grid();
        assert!(g.tiles_intersecting(&[Some((10, 10)), None]).is_err());
        assert!(g.tiles_intersecting(&[Some((0, 999)), None]).is_err());
        assert!(g.tiles_intersecting(&[None]).is_err());
    }

    #[test]
    fn gemm_tile_flops_partial_edges() {
        let g = TileGrid::gemm(100, 64, 64, 64).unwrap();
        let full = g.gemm_tile_flops(0, 128).unwrap();
        assert_eq!(full, 2.0 * 64.0 * 64.0 * 128.0);
        let partial = g.gemm_tile_flops(1, 128).unwrap(); // M tile 1: 36 rows
        assert_eq!(partial, 2.0 * 36.0 * 64.0 * 128.0);
    }
}
