//! Local-kernel model: tile grids, `@sy.*` annotations, tile schedulers.
//!
//! The paper's compute side (§5.2): a local kernel exposes its tiling
//! structure via lightweight annotations — tile size, tile index identifier,
//! and tile scheduler — which Syncopate parses and then *swizzles* so tiles
//! execute in chunk-arrival order (Fig. 6c) without any data reordering.

pub mod annotations;
pub mod grid;
pub mod scheduler;

pub use annotations::{parse_annotations, KernelAnnotations};
pub use grid::{Axis, TileGrid, TileId};
pub use scheduler::{IntraOrder, SwizzlePolicy, TileScheduler};
