//! The chunk abstraction (§5.1 of the paper).
//!
//! A *chunk* is a logical block of data communicated as a unit: an
//! intermediate layout between the global logical tensor and the local
//! compute tiles. Chunks are defined over logical tensor *regions*, never
//! concrete buffers, so the same schedule can be reused across kernels and
//! shapes and specialized late (backend choice, split factor) without
//! re-deriving the plan.


use crate::error::{Error, Result};

/// Element type of a tensor. The real-numerics path is f32-only (CPU PJRT);
/// bf16 participates in the analytic performance models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    BF16,
    F16,
}

impl DType {
    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::BF16 | DType::F16 => 2,
        }
    }
}

/// Index of a tensor within a [`TensorTable`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub u32);

/// A logical tensor participating in a schedule (global shape, not a shard).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorDecl {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorDecl {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.size()
    }
}

/// Registry of tensors referenced by a communication schedule.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TensorTable {
    tensors: Vec<TensorDecl>,
}

impl TensorTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare a tensor; returns its id. Names must be unique.
    pub fn declare(&mut self, name: &str, shape: &[usize], dtype: DType) -> Result<TensorId> {
        if self.tensors.iter().any(|t| t.name == name) {
            return Err(Error::Region(format!("tensor `{name}` already declared")));
        }
        if shape.is_empty() || shape.contains(&0) {
            return Err(Error::Region(format!("tensor `{name}` has empty shape {shape:?}")));
        }
        self.tensors.push(TensorDecl { name: name.into(), shape: shape.to_vec(), dtype });
        Ok(TensorId(self.tensors.len() as u32 - 1))
    }

    pub fn get(&self, id: TensorId) -> Result<&TensorDecl> {
        self.tensors
            .get(id.0 as usize)
            .ok_or_else(|| Error::Region(format!("unknown tensor id {id:?}")))
    }

    pub fn lookup(&self, name: &str) -> Option<TensorId> {
        self.tensors.iter().position(|t| t.name == name).map(|i| TensorId(i as u32))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
    pub fn iter(&self) -> impl Iterator<Item = (TensorId, &TensorDecl)> {
        self.tensors.iter().enumerate().map(|(i, t)| (TensorId(i as u32), t))
    }
}

/// A rectangular (hyper-rectangle) region of a tensor: `offset + sizes`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Region {
    pub offset: Vec<usize>,
    pub sizes: Vec<usize>,
}

impl Region {
    pub fn new(offset: Vec<usize>, sizes: Vec<usize>) -> Self {
        assert_eq!(offset.len(), sizes.len(), "rank mismatch");
        Region { offset, sizes }
    }

    /// Whole-tensor region for a shape.
    pub fn full(shape: &[usize]) -> Self {
        Region { offset: vec![0; shape.len()], sizes: shape.to_vec() }
    }

    /// Region covering rows `[r0, r0+n)` of a 2-D tensor.
    pub fn rows(r0: usize, n: usize, cols: usize) -> Self {
        Region { offset: vec![r0, 0], sizes: vec![n, cols] }
    }

    /// Region covering columns `[c0, c0+n)` of a 2-D `rows x ?` tensor.
    pub fn cols(c0: usize, n: usize, rows: usize) -> Self {
        Region { offset: vec![0, c0], sizes: vec![rows, n] }
    }

    pub fn rank(&self) -> usize {
        self.sizes.len()
    }

    pub fn elems(&self) -> usize {
        self.sizes.iter().product()
    }

    /// True if this region lies inside `shape`.
    pub fn fits(&self, shape: &[usize]) -> bool {
        self.rank() == shape.len()
            && self
                .offset
                .iter()
                .zip(&self.sizes)
                .zip(shape)
                .all(|((o, s), d)| o + s <= *d && *s > 0)
    }

    /// True if the two regions overlap in every dimension.
    pub fn intersects(&self, other: &Region) -> bool {
        if self.rank() != other.rank() {
            return false;
        }
        self.offset
            .iter()
            .zip(&self.sizes)
            .zip(other.offset.iter().zip(&other.sizes))
            .all(|((ao, asz), (bo, bsz))| ao < &(bo + bsz) && bo < &(ao + asz))
    }

    /// True if `other` is entirely contained in `self`.
    pub fn contains(&self, other: &Region) -> bool {
        if self.rank() != other.rank() {
            return false;
        }
        self.offset
            .iter()
            .zip(&self.sizes)
            .zip(other.offset.iter().zip(&other.sizes))
            .all(|((ao, asz), (bo, bsz))| bo >= ao && bo + bsz <= ao + asz)
    }

    /// Is this region contiguous in a row-major layout of `shape`?
    ///
    /// True iff all dims before the first partial dim are size-1 and all dims
    /// after it are full. Copy engines require contiguity per transfer; a
    /// non-contiguous region decomposes into [`Region::contiguous_pieces`].
    pub fn is_contiguous(&self, shape: &[usize]) -> bool {
        self.contiguous_pieces(shape) == 1
    }

    /// Number of maximal contiguous row-major pieces this region splits into.
    ///
    /// This drives the copy-engine launch-count cost model (each piece is a
    /// separate host-launched transfer, §2.3).
    pub fn contiguous_pieces(&self, shape: &[usize]) -> usize {
        assert_eq!(self.rank(), shape.len());
        // Find the last dimension d such that the region spans dims d+1.. fully;
        // everything up to d multiplies into the piece count, except one
        // trailing "free" dim that can vary within a piece.
        let mut pieces = 1usize;
        let mut suffix_full = true;
        for d in (0..self.rank()).rev() {
            if suffix_full {
                if self.sizes[d] == shape[d] {
                    continue; // still inside the contiguous suffix
                }
                // first partial dim from the right: it is free (varies inside
                // one piece); everything left of it multiplies piece count.
                suffix_full = false;
            } else {
                pieces *= self.sizes[d];
            }
        }
        pieces
    }

    /// Visit the row-major linear offset of every element in order,
    /// allocating nothing for rank ≤ [`Region::MAX_STACK_RANK`] (strides
    /// and the odometer live in stack arrays). This is the engine hot
    /// path's streaming alternative to [`Region::linear_offsets`].
    pub fn for_each_offset(&self, shape: &[usize], mut f: impl FnMut(usize)) {
        assert_eq!(self.rank(), shape.len());
        let rank = self.rank();
        if rank > Self::MAX_STACK_RANK {
            // Rare deep-rank fallback: heap-allocating odometer.
            for o in self.linear_offsets_alloc(shape) {
                f(o);
            }
            return;
        }
        let mut strides = [1usize; Self::MAX_STACK_RANK];
        for d in (0..rank.saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * shape[d + 1];
        }
        let mut idx = [0usize; Self::MAX_STACK_RANK];
        loop {
            let mut lin = 0usize;
            for d in 0..rank {
                lin += (idx[d] + self.offset[d]) * strides[d];
            }
            f(lin);
            // odometer increment
            let mut d = rank;
            loop {
                if d == 0 {
                    return;
                }
                d -= 1;
                idx[d] += 1;
                if idx[d] < self.sizes[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
    }

    /// Max tensor rank handled without heap allocation in
    /// [`Region::for_each_offset`].
    pub const MAX_STACK_RANK: usize = 8;

    /// Row-major linear offsets of every element (for buffer copies).
    ///
    /// Only used by the real-numerics executor at small shapes; streaming
    /// callers should prefer [`Region::for_each_offset`].
    pub fn linear_offsets(&self, shape: &[usize]) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.elems());
        self.for_each_offset(shape, |o| out.push(o));
        out
    }

    /// Heap-allocating odometer for regions deeper than
    /// [`Region::MAX_STACK_RANK`].
    fn linear_offsets_alloc(&self, shape: &[usize]) -> Vec<usize> {
        let mut strides = vec![1usize; shape.len()];
        for d in (0..shape.len().saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * shape[d + 1];
        }
        let mut out = Vec::with_capacity(self.elems());
        let mut idx = vec![0usize; self.rank()];
        loop {
            let lin: usize = idx
                .iter()
                .zip(&self.offset)
                .zip(&strides)
                .map(|((i, o), s)| (i + o) * s)
                .sum();
            out.push(lin);
            // odometer increment
            let mut d = self.rank();
            loop {
                if d == 0 {
                    return out;
                }
                d -= 1;
                idx[d] += 1;
                if idx[d] < self.sizes[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
    }

    /// Split along `axis` into `n` equal sub-regions (the split factor knob).
    pub fn split(&self, axis: usize, n: usize) -> Result<Vec<Region>> {
        if axis >= self.rank() {
            return Err(Error::Region(format!("axis {axis} out of rank {}", self.rank())));
        }
        if n == 0 || self.sizes[axis] % n != 0 {
            return Err(Error::Region(format!(
                "cannot split size {} on axis {axis} into {n} equal parts",
                self.sizes[axis]
            )));
        }
        let step = self.sizes[axis] / n;
        Ok((0..n)
            .map(|i| {
                let mut off = self.offset.clone();
                let mut sz = self.sizes.clone();
                off[axis] += i * step;
                sz[axis] = step;
                Region { offset: off, sizes: sz }
            })
            .collect())
    }
}

/// A chunk: a tensor region communicated as a unit (paper §5.1).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Chunk {
    pub tensor: TensorId,
    pub region: Region,
}

impl Chunk {
    pub fn new(tensor: TensorId, region: Region) -> Self {
        Chunk { tensor, region }
    }

    /// Bytes moved when this chunk is transferred.
    pub fn bytes(&self, table: &TensorTable) -> Result<usize> {
        Ok(self.region.elems() * table.get(self.tensor)?.dtype.size())
    }

    /// Check the chunk's region against its tensor's declared shape.
    pub fn validate(&self, table: &TensorTable) -> Result<()> {
        let t = table.get(self.tensor)?;
        if !self.region.fits(&t.shape) {
            return Err(Error::Region(format!(
                "chunk region {:?} does not fit tensor `{}` shape {:?}",
                self.region, t.name, t.shape
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> (TensorTable, TensorId) {
        let mut t = TensorTable::new();
        let id = t.declare("x", &[8, 16], DType::F32).unwrap();
        (t, id)
    }

    #[test]
    fn declare_and_lookup() {
        let (t, id) = table();
        assert_eq!(t.lookup("x"), Some(id));
        assert_eq!(t.lookup("y"), None);
        assert_eq!(t.get(id).unwrap().bytes(), 8 * 16 * 4);
    }

    #[test]
    fn duplicate_declare_rejected() {
        let (mut t, _) = table();
        assert!(t.declare("x", &[2], DType::F32).is_err());
    }

    #[test]
    fn empty_shape_rejected() {
        let mut t = TensorTable::new();
        assert!(t.declare("bad", &[4, 0], DType::F32).is_err());
        assert!(t.declare("bad2", &[], DType::F32).is_err());
    }

    #[test]
    fn region_fits_and_elems() {
        let r = Region::rows(2, 4, 16);
        assert!(r.fits(&[8, 16]));
        assert!(!r.fits(&[5, 16]));
        assert_eq!(r.elems(), 64);
        assert!(!Region::new(vec![0], vec![4]).fits(&[8, 16])); // rank mismatch
    }

    #[test]
    fn region_intersects_contains() {
        let a = Region::rows(0, 4, 16);
        let b = Region::rows(2, 4, 16);
        let c = Region::rows(4, 4, 16);
        assert!(a.intersects(&b));
        assert!(!a.intersects(&c));
        assert!(Region::full(&[8, 16]).contains(&a));
        assert!(!a.contains(&b));
        assert!(a.contains(&Region::rows(1, 2, 16)));
    }

    #[test]
    fn contiguity_row_major() {
        // full rows of a [8,16] tensor are contiguous
        assert!(Region::rows(2, 3, 16).is_contiguous(&[8, 16]));
        // a column slab is not: one piece per row
        let col = Region::cols(0, 4, 8);
        assert!(!col.is_contiguous(&[8, 16]));
        assert_eq!(col.contiguous_pieces(&[8, 16]), 8);
        // full tensor is a single piece
        assert_eq!(Region::full(&[8, 16]).contiguous_pieces(&[8, 16]), 1);
        // single element: contiguous
        assert!(Region::new(vec![3, 7], vec![1, 1]).is_contiguous(&[8, 16]));
    }

    #[test]
    fn contiguity_3d() {
        let shape = [4, 8, 16];
        // full planes
        assert!(Region::new(vec![1, 0, 0], vec![2, 8, 16]).is_contiguous(&shape));
        // partial middle dim: pieces = leading size
        let r = Region::new(vec![0, 2, 0], vec![4, 3, 16]);
        assert_eq!(r.contiguous_pieces(&shape), 4);
        // partial last dim: pieces = product of leading sizes
        let r2 = Region::new(vec![0, 0, 4], vec![4, 8, 8]);
        assert_eq!(r2.contiguous_pieces(&shape), 32);
    }

    #[test]
    fn linear_offsets_row_region() {
        let r = Region::rows(1, 2, 4);
        let offs = r.linear_offsets(&[4, 4]);
        assert_eq!(offs, vec![4, 5, 6, 7, 8, 9, 10, 11]);
    }

    #[test]
    fn linear_offsets_col_region() {
        let r = Region::cols(1, 2, 3);
        let offs = r.linear_offsets(&[3, 4]);
        assert_eq!(offs, vec![1, 2, 5, 6, 9, 10]);
    }

    #[test]
    fn for_each_offset_matches_alloc_odometer() {
        // stack-array path vs the heap odometer, across ranks and strides
        let cases: Vec<(Region, Vec<usize>)> = vec![
            (Region::rows(1, 2, 4), vec![4, 4]),
            (Region::cols(1, 2, 3), vec![3, 4]),
            (Region::new(vec![1, 0, 2], vec![2, 3, 2]), vec![4, 3, 4]),
            (Region::new(vec![0], vec![5]), vec![5]),
            // rank 9 exercises the > MAX_STACK_RANK fallback
            (Region::new(vec![0; 9], vec![1, 2, 1, 2, 1, 2, 1, 2, 1]), vec![2; 9]),
        ];
        for (r, shape) in cases {
            let mut streamed = Vec::new();
            r.for_each_offset(&shape, |o| streamed.push(o));
            assert_eq!(streamed, r.linear_offsets_alloc(&shape), "region {r:?}");
            assert_eq!(streamed, r.linear_offsets(&shape));
            assert_eq!(streamed.len(), r.elems());
        }
    }

    #[test]
    fn split_rows() {
        let r = Region::full(&[8, 16]);
        let parts = r.split(0, 4).unwrap();
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[1], Region::rows(2, 2, 16));
        let total: usize = parts.iter().map(|p| p.elems()).sum();
        assert_eq!(total, r.elems());
    }

    #[test]
    fn split_errors() {
        let r = Region::full(&[8, 16]);
        assert!(r.split(2, 2).is_err()); // bad axis
        assert!(r.split(0, 3).is_err()); // non-dividing
        assert!(r.split(0, 0).is_err()); // zero
    }

    #[test]
    fn chunk_bytes_and_validate() {
        let (t, id) = table();
        let c = Chunk::new(id, Region::rows(0, 4, 16));
        assert_eq!(c.bytes(&t).unwrap(), 4 * 16 * 4);
        assert!(c.validate(&t).is_ok());
        let bad = Chunk::new(id, Region::rows(6, 4, 16));
        assert!(bad.validate(&t).is_err());
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::BF16.size(), 2);
        assert_eq!(DType::F16.size(), 2);
    }
}
