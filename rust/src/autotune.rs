//! Communication-centric auto-tuning (paper §5.3).
//!
//! The search space is the chunk abstraction's knob set: inter-chunk (split
//! factor) × intra-chunk (backend realization, SM allocation, tile shape,
//! tile order). Candidates violating hardware limits are pruned before
//! simulation (backend capability matrix, minimum efficient transfer size,
//! divisibility); the rest are scored on the calibrated model. Because every
//! candidate reuses the same chunk-level dependence structure, changing a
//! knob never re-derives the global plan — `compile_operator` re-lowers the
//! same schedule under the new realization, exactly as §5.3 describes.

use crate::backend::{self, BackendKind};
use crate::codegen::Realization;
use crate::coordinator::operators::compile_operator;
use crate::coordinator::TuneConfig;
use crate::error::{Error, Result};
use crate::kernel::scheduler::{IntraOrder, SwizzlePolicy};
use crate::sim::engine::simulate;
use crate::topo::Topology;
use crate::workload::{OpKind, OperatorInstance};

/// Search-space size control.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Budget {
    /// Coarse sweep (fast; used inside larger benchmark loops).
    Quick,
    /// Full factorial sweep of the documented knobs.
    Full,
}

/// Outcome of a tuning run.
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub cfg: TuneConfig,
    pub makespan_us: f64,
    pub tflops: f64,
    /// Candidates actually simulated.
    pub evaluated: usize,
    /// Candidates pruned by hardware limits before simulation.
    pub pruned: usize,
    /// (config label, makespan) for every evaluated candidate.
    pub log: Vec<(String, f64)>,
}

/// Minimum transfer size below which the copy engine's launch overhead
/// dominates (the "minimum efficient transfer size" prune of §5.3).
pub const MIN_CE_CHUNK_BYTES: usize = 64 * 1024;

/// Enumerate the candidate configurations for an operator on a topology
/// (the arch matrix decides which backends take SM-allocation choices).
pub fn search_space(op: &OperatorInstance, topo: &Topology, budget: Budget) -> Vec<TuneConfig> {
    let splits: &[usize] = match budget {
        Budget::Quick => &[1, 2, 4],
        Budget::Full => &[1, 2, 4, 8, 16],
    };
    let sms: &[usize] = match budget {
        Budget::Quick => &[16, 32],
        Budget::Full => &[8, 16, 32, 64],
    };
    let blocks: &[(usize, usize, usize)] = match budget {
        Budget::Quick => &[(128, 128, 128)],
        Budget::Full => &[(128, 128, 128), (64, 128, 128), (128, 256, 64), (256, 128, 128)],
    };
    let swizzles = [
        SwizzlePolicy::ChunkMajor { intra: IntraOrder::Snake },
        SwizzlePolicy::ChunkMajor { intra: IntraOrder::RowMajor },
        SwizzlePolicy::RowMajor,
    ];
    let mut out = Vec::new();
    for &split in splits {
        for backend in BackendKind::TUNABLE {
            let sm_choices: Vec<usize> = if topo.arch.curve(backend).sms_for_peak == 0 {
                vec![0]
            } else {
                sms.to_vec()
            };
            for &comm_sms in &sm_choices {
                for swizzle in &swizzles {
                    for &(bm, bn, bk) in blocks {
                        out.push(TuneConfig {
                            split,
                            real: Realization::new(backend, comm_sms),
                            swizzle: swizzle.clone(),
                            block_m: bm,
                            block_n: bn,
                            block_k: bk,
                        });
                    }
                }
            }
        }
    }
    // attention operators ignore block_n/k variation; dedupe by label
    if !op.kind.is_gemm() {
        out.dedup_by(|a, b| a.label() == b.label());
    }
    out
}

/// Hardware-limit pre-pruning (no simulation needed to reject these).
pub fn prune(op: &OperatorInstance, cfg: &TuneConfig, topo: &Topology) -> Result<()> {
    let needs_reduce = matches!(op.kind, OpKind::GemmRs | OpKind::GemmAr);
    let multi_node = topo.ranks_per_node < topo.world;
    let level = if multi_node {
        crate::topo::LinkLevel::InterNode
    } else {
        crate::topo::LinkLevel::IntraNode
    };
    // arch-aware: rejects mechanisms the machine generation lacks entirely
    // (e.g. TMA on a100_node) before the shared capability rules
    topo.arch.check_feasible(cfg.real.backend, needs_reduce, level, cfg.real.comm_sms)?;
    // minimum efficient transfer size for the copy engine
    if cfg.real.backend == BackendKind::CopyEngine {
        let shard_bytes = op.comm_bytes() / op.world.max(1) / (op.world.max(2) - 1).max(1);
        let chunk_bytes = shard_bytes / cfg.split.max(1);
        if chunk_bytes < MIN_CE_CHUNK_BYTES {
            return Err(Error::Autotune(format!(
                "chunk {} B below copy-engine minimum {}",
                chunk_bytes, MIN_CE_CHUNK_BYTES
            )));
        }
    }
    // reserving more SMs than the device has is nonsense
    if cfg.real.comm_sms >= topo.sms_per_device {
        return Err(Error::Autotune("comm SMs exceed device".into()));
    }
    Ok(())
}

/// Tune one operator: enumerate, prune, simulate, keep the best.
pub fn tune(op: &OperatorInstance, topo: &Topology, budget: Budget) -> Result<TuneResult> {
    let mut best: Option<(TuneConfig, f64, f64)> = None;
    let mut evaluated = 0usize;
    let mut pruned = 0usize;
    let mut log = Vec::new();
    for cfg in search_space(op, topo, budget) {
        if prune(op, &cfg, topo).is_err() {
            pruned += 1;
            continue;
        }
        // divisibility and similar structural failures also count as pruned
        let (plan, params) = match compile_operator(op, &cfg, topo) {
            Ok(x) => x,
            Err(_) => {
                pruned += 1;
                continue;
            }
        };
        let r = match simulate(&plan, topo, params) {
            Ok(r) => r,
            Err(_) => {
                pruned += 1;
                continue;
            }
        };
        evaluated += 1;
        log.push((cfg.label(), r.makespan_us));
        let better = best.as_ref().map(|(_, t, _)| r.makespan_us < *t).unwrap_or(true);
        if better {
            best = Some((cfg, r.makespan_us, r.tflops()));
        }
    }
    let (cfg, makespan_us, tflops) = best.ok_or_else(|| {
        Error::Autotune(format!(
            "no feasible configuration for {} ({} pruned)",
            op.label(),
            pruned
        ))
    })?;
    Ok(TuneResult { cfg, makespan_us, tflops, evaluated, pruned, log })
}

/// Outcome of restricted user-plan tuning.
#[derive(Debug, Clone)]
pub struct PlanTuneResult {
    /// Best backend realization found.
    pub real: Realization,
    /// Simulated comm-only makespan under it.
    pub makespan_us: f64,
    pub evaluated: usize,
    pub pruned: usize,
}

/// Restricted autotune for user-submitted plans (DESIGN.md §11): only the
/// *intra-chunk* knobs — backend and communication-SM allocation — are
/// searched. The inter-chunk split factor is FIXED by the plan itself: a
/// user or a foreign compiler who wrote explicit chunk regions meant them,
/// and re-splitting would silently change the artifact being served.
pub fn tune_user_plan(
    sched: &crate::schedule::CommSchedule,
    topo: &Topology,
) -> Result<PlanTuneResult> {
    // Abstract collectives fail for EVERY realization at codegen; name the
    // real cause instead of reporting a misleading exhausted search.
    if sched
        .per_rank
        .iter()
        .flatten()
        .any(|op| matches!(op, crate::schedule::CommOp::Collective { .. }))
    {
        return Err(Error::Autotune(
            "plan contains abstract collective ops; lower them to P2P \
             (lowering::collective) before serving"
                .into(),
        ));
    }
    let mut best: Option<(Realization, f64)> = None;
    let mut evaluated = 0usize;
    let mut pruned = 0usize;
    let mut last_err: Option<Error> = None;
    for backend in BackendKind::TUNABLE {
        let sm_choices: &[usize] = if topo.arch.curve(backend).sms_for_peak == 0 {
            &[0]
        } else {
            &[8, 16, 32]
        };
        for &comm_sms in sm_choices {
            if comm_sms >= topo.sms_per_device {
                pruned += 1;
                continue;
            }
            let real = Realization::new(backend, comm_sms);
            // capability violations (reduce on TMA, copy engine across
            // nodes, ...) surface as compile errors per transfer
            let r = crate::codegen::compile_comm_only(sched, real, topo)
                .and_then(|plan| simulate(&plan, topo, crate::sim::SimParams::default()));
            match r {
                Ok(r) => {
                    evaluated += 1;
                    if best.as_ref().map(|(_, t)| r.makespan_us < *t).unwrap_or(true) {
                        best = Some((real, r.makespan_us));
                    }
                }
                Err(e) => {
                    pruned += 1;
                    last_err = Some(e);
                }
            }
        }
    }
    let (real, makespan_us) = best.ok_or_else(|| {
        let cause = last_err
            .map(|e| format!("; last failure: {e}"))
            .unwrap_or_default();
        Error::Autotune(format!(
            "no feasible realization for the submitted plan ({pruned} pruned{cause})"
        ))
    })?;
    Ok(PlanTuneResult { real, makespan_us, evaluated, pruned })
}

// ---------------------------------------------------------------------------
// Tuned-configuration persistence: tune once, reuse across processes.
// TSV format (one row per entry):
//   operator \t topology fingerprint \t config \t makespan \t tflops \t source
// The fingerprint (hw::fingerprint: structural hash of world, links, device
// and the backend matrix) is part of the KEY: a cache persisted on one
// machine shape can never serve stale knobs on another — tuned splits and
// backends are only optimal for the curves they were scored on.
// `source` records where the time came from — `modeled` (simulator) or
// `measured` (a traced execution); measured entries outrank modeled ones.
// Five-column files from before the source column parse as `modeled`.
// (The offline build has no serde; labels round-trip as plain text.)
// ---------------------------------------------------------------------------

/// Where a cached time came from: the calibrated model, or an actual
/// traced execution. Measured beats modeled — a modeled insert never
/// overwrites a measured entry for the same key, while a measured insert
/// overwrites anything.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeSource {
    Modeled,
    Measured,
}

impl TimeSource {
    pub fn name(self) -> &'static str {
        match self {
            TimeSource::Modeled => "modeled",
            TimeSource::Measured => "measured",
        }
    }

    pub fn by_name(s: &str) -> Option<TimeSource> {
        match s {
            "modeled" => Some(TimeSource::Modeled),
            "measured" => Some(TimeSource::Measured),
            _ => None,
        }
    }
}

/// On-disk tuning cache, keyed by (operator label, topology fingerprint).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TuneCache {
    entries: Vec<(String, String, String, f64, f64, TimeSource)>,
}

impl TuneCache {
    /// Record a result for an operator tuned on `topo`. Fails with
    /// [`Error::Autotune`] when a label embeds a tab or newline — the TSV
    /// format's structural characters — instead of writing a cache file
    /// that parses back into different (or silently merged) entries.
    pub fn insert(
        &mut self,
        op: &OperatorInstance,
        topo: &Topology,
        r: &TuneResult,
    ) -> Result<()> {
        self.insert_raw(
            &op.label(),
            &crate::hw::fingerprint(topo),
            &r.cfg.label(),
            r.makespan_us,
            r.tflops,
        )
    }

    /// Label-level insert of a MODELED time for callers with non-registry
    /// labels; the same structural-character validation applies.
    pub fn insert_raw(
        &mut self,
        op_label: &str,
        topo_fp: &str,
        cfg_label: &str,
        m: f64,
        t: f64,
    ) -> Result<()> {
        self.insert_with_source(op_label, topo_fp, cfg_label, m, t, TimeSource::Modeled)
    }

    /// Record a MEASURED time (from a traced execution). Overwrites any
    /// existing entry for the key.
    pub fn insert_measured_raw(
        &mut self,
        op_label: &str,
        topo_fp: &str,
        cfg_label: &str,
        m: f64,
        t: f64,
    ) -> Result<()> {
        self.insert_with_source(op_label, topo_fp, cfg_label, m, t, TimeSource::Measured)
    }

    fn insert_with_source(
        &mut self,
        op_label: &str,
        topo_fp: &str,
        cfg_label: &str,
        m: f64,
        t: f64,
        source: TimeSource,
    ) -> Result<()> {
        for (what, s) in [
            ("operator label", op_label),
            ("topology fingerprint", topo_fp),
            ("config label", cfg_label),
        ] {
            if s.contains('\t') || s.contains('\n') {
                return Err(Error::Autotune(format!(
                    "cannot cache {what} {s:?}: embedded tab/newline would corrupt \
                     the TSV cache"
                )));
            }
        }
        // measured wins: a modeled time never displaces a measured one
        if source == TimeSource::Modeled
            && self.entries.iter().any(|(l, fp, _, _, _, s)| {
                l == op_label && fp == topo_fp && *s == TimeSource::Measured
            })
        {
            return Ok(());
        }
        self.entries.retain(|(l, fp, ..)| !(l == op_label && fp == topo_fp));
        self.entries.push((
            op_label.to_string(),
            topo_fp.to_string(),
            cfg_label.to_string(),
            m,
            t,
            source,
        ));
        Ok(())
    }

    /// Look up a cached config label for an operator ON THIS topology;
    /// entries tuned for any other machine shape never match.
    pub fn get(&self, op: &OperatorInstance, topo: &Topology) -> Option<(&str, f64, f64)> {
        self.get_with_source(op, topo).map(|(c, m, t, _)| (c, m, t))
    }

    /// [`TuneCache::get`] + where the time came from. Every lookup lands
    /// in `tune_cache.lookups{result=modeled|measured|miss}` so a serving
    /// tier can watch how much of its tuning is backed by real traces.
    pub fn get_with_source(
        &self,
        op: &OperatorInstance,
        topo: &Topology,
    ) -> Option<(&str, f64, f64, TimeSource)> {
        let fp = crate::hw::fingerprint(topo);
        let found = self
            .entries
            .iter()
            .find(|(l, f, ..)| l == &op.label() && f == &fp)
            .map(|(_, _, c, m, t, s)| (c.as_str(), *m, *t, *s));
        let result = match &found {
            Some((.., s)) => s.name(),
            None => "miss",
        };
        crate::obs::counter_with("tune_cache.lookups", &[("result", result)]).inc();
        found
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize to TSV.
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        for (op, fp, cfg, m, t, s) in &self.entries {
            // `{}` prints the shortest representation that round-trips f64
            out.push_str(&format!("{op}\t{fp}\t{cfg}\t{m}\t{t}\t{}\n", s.name()));
        }
        out
    }

    /// Parse from TSV (5 legacy columns = modeled, 6 with a source tag).
    pub fn from_tsv(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            // splitn keeps any surplus tabs inside the last fragment, where
            // the tag/float parse rejects them — a line can never contribute
            // more than one entry however mangled its labels are
            let cols: Vec<&str> = line.splitn(6, '\t').collect();
            if cols.len() < 5 {
                return Err(Error::Autotune(format!(
                    "cache line {}: need 5 or 6 tab-separated cols \
                     (op, topo-fingerprint, config, makespan, tflops[, source])",
                    i + 1
                )));
            }
            let m: f64 = cols[3]
                .parse()
                .map_err(|_| Error::Autotune(format!("cache line {}: bad makespan", i + 1)))?;
            let t_col = cols[4];
            let (t_str, source) = if cols.len() == 6 {
                let src = TimeSource::by_name(cols[5]).ok_or_else(|| {
                    Error::Autotune(format!(
                        "cache line {}: unknown source `{}` (modeled|measured)",
                        i + 1,
                        cols[5]
                    ))
                })?;
                (t_col, src)
            } else {
                // legacy 5-column row: modeled (predates the source column)
                if t_col.contains('\t') {
                    return Err(Error::Autotune(format!(
                        "cache line {}: need 5 or 6 tab-separated cols",
                        i + 1
                    )));
                }
                (t_col, TimeSource::Modeled)
            };
            let t: f64 = t_str
                .parse()
                .map_err(|_| Error::Autotune(format!("cache line {}: bad tflops", i + 1)))?;
            entries.push((
                cols[0].to_string(),
                cols[1].to_string(),
                cols[2].to_string(),
                m,
                t,
                source,
            ));
        }
        Ok(TuneCache { entries })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_tsv())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_tsv(&std::fs::read_to_string(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{OperatorInstance, LLAMA3_8B, LLAMA3_70B};

    fn topo() -> Topology {
        crate::hw::catalog::topology("h100_node", 4).unwrap()
    }

    #[test]
    fn space_enumerates_and_scales_with_budget() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let t4 = topo();
        let q = search_space(&op, &t4, Budget::Quick).len();
        let f = search_space(&op, &t4, Budget::Full).len();
        assert!(q >= 20, "{q}");
        assert!(f > 4 * q, "{f} vs {q}");
    }

    #[test]
    fn prune_rejects_reduce_on_tma() {
        let op = OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_8B, 4096, 4);
        let cfg = TuneConfig {
            real: Realization::new(BackendKind::TmaSpecialized, 16),
            ..Default::default()
        };
        assert!(prune(&op, &cfg, &topo()).is_err());
        let ok = TuneConfig {
            real: Realization::new(BackendKind::LdStSpecialized, 16),
            ..Default::default()
        };
        assert!(prune(&op, &ok, &topo()).is_ok());
    }

    #[test]
    fn prune_rejects_tiny_ce_chunks() {
        // tiny operator: shards far below the CE minimum once split
        let mut op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        op.m = 64;
        op.k = 64;
        let cfg = TuneConfig { split: 16, ..Default::default() };
        assert!(prune(&op, &cfg, &topo()).is_err());
    }

    #[test]
    fn tune_finds_feasible_best_quick() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let r = tune(&op, &topo(), Budget::Quick).unwrap();
        assert!(r.evaluated > 0);
        assert!(r.tflops > 10.0, "{}", r.tflops);
        // best is the min of the log
        let min = r.log.iter().map(|(_, t)| *t).fold(f64::INFINITY, f64::min);
        assert_eq!(min, r.makespan_us);
    }

    #[test]
    fn tuned_beats_median_candidate() {
        // §5.3: suboptimal settings can leave >2x on the table; the tuned
        // config must at least beat the median of the space.
        let op = OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_70B, 8192, 4);
        let r = tune(&op, &topo(), Budget::Quick).unwrap();
        let mut times: Vec<f64> = r.log.iter().map(|(_, t)| *t).collect();
        times.sort_by(f64::total_cmp);
        let median = times[times.len() / 2];
        assert!(r.makespan_us < median, "best {} median {median}", r.makespan_us);
    }

    #[test]
    fn cache_roundtrip_and_replace() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let t4 = topo();
        let r = tune(&op, &t4, Budget::Quick).unwrap();
        let mut c = TuneCache::default();
        assert!(c.is_empty());
        c.insert(&op, &t4, &r).unwrap();
        assert_eq!(c.len(), 1);
        let (cfg, m, t) = c.get(&op, &t4).unwrap();
        assert_eq!(cfg, r.cfg.label());
        assert_eq!(m, r.makespan_us);
        assert_eq!(t, r.tflops);
        // TSV round trip
        let c2 = TuneCache::from_tsv(&c.to_tsv()).unwrap();
        assert_eq!(c, c2);
        // replacing an entry keeps the cache deduped
        c.insert(&op, &t4, &r).unwrap();
        assert_eq!(c.len(), 1);
        // parse errors (incl. the legacy 4-column format, which predates
        // the topology-fingerprint key and must be rejected, not misread)
        assert!(TuneCache::from_tsv("a\tb\tc\n").is_err());
        assert!(TuneCache::from_tsv("a\tb\t1.0\t2.0\n").is_err());
        assert!(TuneCache::from_tsv("a\tfp\tb\tx\t1\n").is_err());
        assert!(TuneCache::from_tsv("").unwrap().is_empty());
    }

    #[test]
    fn cache_save_load_file() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let t4 = topo();
        let r = tune(&op, &t4, Budget::Quick).unwrap();
        let mut c = TuneCache::default();
        c.insert(&op, &t4, &r).unwrap();
        let path = std::env::temp_dir().join("syncopate_tune_cache_test.tsv");
        c.save(&path).unwrap();
        let loaded = TuneCache::load(&path).unwrap();
        assert_eq!(c, loaded);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cache_never_serves_across_machine_shapes() {
        // ISSUE 4 satellite (poisoning regression): a cache persisted on
        // one machine shape must not serve its knobs on another — neither
        // a different arch, nor the same arch at a different world.
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let h100 = topo();
        let r = tune(&op, &h100, Budget::Quick).unwrap();
        let mut c = TuneCache::default();
        c.insert(&op, &h100, &r).unwrap();
        assert!(c.get(&op, &h100).is_some());
        let a100 = crate::hw::catalog::topology("a100_node", 4).unwrap();
        assert!(c.get(&op, &a100).is_none(), "a100 must miss an h100-tuned entry");
        let h100_w8 = crate::hw::catalog::topology("h100_node", 8).unwrap();
        assert!(c.get(&op, &h100_w8).is_none(), "world 8 must miss a world-4 entry");
        // both shapes coexist under the same operator label...
        let r_a100 = tune(&op, &a100, Budget::Quick).unwrap();
        c.insert(&op, &a100, &r_a100).unwrap();
        assert_eq!(c.len(), 2);
        // ...and survive the TSV round trip with their fingerprints intact
        let reloaded = TuneCache::from_tsv(&c.to_tsv()).unwrap();
        assert_eq!(c, reloaded);
        assert_eq!(reloaded.get(&op, &h100).unwrap().0, r.cfg.label());
        assert_eq!(reloaded.get(&op, &a100).unwrap().0, r_a100.cfg.label());
    }

    #[test]
    fn cache_roundtrips_every_suite_label() {
        // ISSUE 3 satellite: every fig8/fig9 operator label (and the
        // default config label) must survive the TSV round trip verbatim
        let mut c = TuneCache::default();
        let t4 = topo();
        let ops: Vec<_> =
            crate::workload::fig8_suite().into_iter().chain(crate::workload::fig9_suite()).collect();
        for (i, op) in ops.iter().enumerate() {
            let r = TuneResult {
                cfg: TuneConfig::default(),
                makespan_us: 1.25 * (i + 1) as f64,
                tflops: 0.5 * (i + 1) as f64,
                evaluated: 1,
                pruned: 0,
                log: vec![],
            };
            c.insert(op, &t4, &r).unwrap_or_else(|e| panic!("{}: {e}", op.label()));
        }
        assert_eq!(c.len(), ops.len(), "suite labels must be distinct");
        let reloaded = TuneCache::from_tsv(&c.to_tsv()).unwrap();
        assert_eq!(c, reloaded);
        for op in &ops {
            assert!(reloaded.get(op, &t4).is_some(), "{} lost in round trip", op.label());
        }
    }

    #[test]
    fn cache_rejects_structural_characters_in_labels() {
        let mut c = TuneCache::default();
        for bad in ["tab\tlabel", "newline\nlabel"] {
            let e = c.insert_raw(bad, "fp", "cfg", 1.0, 2.0).unwrap_err();
            assert!(matches!(e, Error::Autotune(_)), "{e:?}");
            assert!(e.to_string().contains("corrupt"), "{e}");
            let e = c.insert_raw("op", bad, "cfg", 1.0, 2.0).unwrap_err();
            assert!(e.to_string().contains("corrupt"), "{e}");
            let e = c.insert_raw("op", "fp", bad, 1.0, 2.0).unwrap_err();
            assert!(e.to_string().contains("corrupt"), "{e}");
        }
        assert!(c.is_empty(), "rejected inserts must not partially apply");
        // a mangled file can never smuggle extra columns into an entry
        assert!(TuneCache::from_tsv("a\tfp\tb\t1.0\t2.0\textra\n").is_err());
    }

    #[test]
    fn measured_times_outrank_modeled_ones() {
        // ISSUE 5 satellite: the cache accepts measured (traced-execution)
        // times next to modeled ones; measured wins on conflict and the
        // source tag survives the TSV round trip.
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let t4 = topo();
        let fp = crate::hw::fingerprint(&t4);
        let mut c = TuneCache::default();
        c.insert_raw(&op.label(), &fp, "cfg-a", 100.0, 1.0).unwrap();
        assert_eq!(c.get_with_source(&op, &t4).unwrap().3, TimeSource::Modeled);
        // measured overwrites modeled
        c.insert_measured_raw(&op.label(), &fp, "cfg-a", 250.0, 0.4).unwrap();
        let (_, m, _, s) = c.get_with_source(&op, &t4).unwrap();
        assert_eq!((m, s), (250.0, TimeSource::Measured));
        assert_eq!(c.len(), 1);
        // a later modeled insert silently yields to the measurement
        c.insert_raw(&op.label(), &fp, "cfg-b", 90.0, 1.1).unwrap();
        let (cfg, m, _, s) = c.get_with_source(&op, &t4).unwrap();
        assert_eq!((cfg, m, s), ("cfg-a", 250.0, TimeSource::Measured));
        // round trip keeps the tag; legacy 5-col rows read as modeled
        let reloaded = TuneCache::from_tsv(&c.to_tsv()).unwrap();
        assert_eq!(c, reloaded);
        assert!(reloaded.to_tsv().contains("\tmeasured\n"));
        let legacy = TuneCache::from_tsv(&format!("{}\t{fp}\tcfg\t1.5\t2.5\n", op.label())).unwrap();
        assert_eq!(legacy.get_with_source(&op, &t4).unwrap().3, TimeSource::Modeled);
        // unknown tags rejected
        assert!(TuneCache::from_tsv("a\tfp\tb\t1.0\t2.0\tguessed\n").is_err());
    }

    #[test]
    fn user_plan_tuning_is_restricted_to_intra_chunk_knobs() {
        use crate::chunk::{DType, TensorTable};
        use crate::schedule::templates;
        let topo = topo();
        let mut t = TensorTable::new();
        let x = t.declare("x", &[64, 64], DType::F32).unwrap();

        // non-reduce plan: something feasible must be found
        let ag = templates::all_gather_swizzle(&t, x, 0, 4).unwrap();
        let r = tune_user_plan(&ag, &topo).unwrap();
        assert!(r.evaluated > 0);
        assert!(r.makespan_us > 0.0);

        // reduce plan: only reduce-capable backends may win
        let rs = templates::reduce_scatter_direct(&t, x, 0, 4).unwrap();
        let r = tune_user_plan(&rs, &topo).unwrap();
        assert!(backend::caps(r.real.backend).supports_reduce);
        assert!(r.pruned > 0, "reduce-incapable realizations must be pruned");

        // the plan's chunking is untouched: tuning consumes the schedule
        // read-only (split factor is whatever the author wrote)
        assert_eq!(rs.num_ops(), templates::reduce_scatter_direct(&t, x, 0, 4).unwrap().num_ops());
    }

    #[test]
    fn tune_reduce_op_never_picks_nonreduce_backend() {
        let op = OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_8B, 4096, 4);
        let r = tune(&op, &topo(), Budget::Quick).unwrap();
        assert!(backend::caps(r.cfg.real.backend).supports_reduce);
        assert!(r.pruned > 0);
    }

    #[test]
    fn tune_on_a100_never_picks_an_arch_absent_mechanism() {
        // A100 ships no TMA rows: the capability matrix must prune both
        // TMA realizations out of the search without any TMA-specific code.
        let a100 = crate::hw::catalog::topology("a100_node", 4).unwrap();
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let r = tune(&op, &a100, Budget::Quick).unwrap();
        assert!(a100.arch.available(r.cfg.real.backend), "{:?}", r.cfg.real.backend);
        assert!(
            !matches!(
                r.cfg.real.backend,
                BackendKind::TmaSpecialized | BackendKind::TmaColocated
            ),
            "{:?}",
            r.cfg.real.backend
        );
        assert!(r.pruned > 0, "TMA candidates must be pruned on a100");
        // restricted user-plan tuning obeys the same matrix
        use crate::chunk::{DType, TensorTable};
        let mut t = TensorTable::new();
        let x = t.declare("x", &[64, 64], DType::F32).unwrap();
        let ag = crate::schedule::templates::all_gather_swizzle(&t, x, 0, 4).unwrap();
        let ur = tune_user_plan(&ag, &a100).unwrap();
        assert!(a100.arch.available(ur.real.backend), "{:?}", ur.real.backend);
        assert!(ur.pruned > 0);
    }
}
