//! Artifact runtime: execute the L1/L2 compute kernels from Rust.
//!
//! Two interchangeable backends sit behind one `Runtime` type:
//!
//! * **PJRT** (feature `xla`) — load the AOT artifacts (HLO text) produced
//!   by `make artifacts` (python/compile/aot.py, listed in
//!   `artifacts/manifest.tsv`) and execute them through the `xla` crate's
//!   PJRT CPU client. Interchange is HLO *text*, not serialized protos:
//!   jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//!   rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//! * **Host reference** (always available, default) — a pure-Rust
//!   interpreter of the canonical kernel families
//!   (`gemm_*`, `attn_step_*`, `attn_finalize_*`, `ffn_shard_*`, `add_*`)
//!   backed by the `exec::verify` oracles, which mirror the Pallas kernels.
//!   It needs no artifacts and no external dependencies, so a bare checkout
//!   builds and tests the full execution stack.
//!
//! The runtime is `Send + Sync`: the parallel executor's rank threads share
//! one instance (executable caching behind a `Mutex`, call accounting in an
//! `AtomicU64`). Both backends are deterministic per call, which the
//! cross-mode bit-identity verifier relies on.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::exec::verify::{host_attn_finalize, host_attn_step, host_ffn_shard, host_gemm};

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Spec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl Spec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn f32(shape: &[usize]) -> Spec {
        Spec { shape: shape.to_vec(), dtype: "float32".into() }
    }
}

/// One manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<Spec>,
    pub outputs: Vec<Spec>,
}

/// Parse `manifest.tsv` (written by aot.py alongside manifest.json).
pub fn parse_manifest(text: &str) -> Result<Vec<Entry>> {
    let parse_specs = |s: &str| -> Result<Vec<Spec>> {
        if s.is_empty() {
            return Ok(vec![]);
        }
        s.split(';')
            .map(|item| {
                let (dims, dtype) = item
                    .split_once(',')
                    .ok_or_else(|| Error::Io(format!("bad spec `{item}`")))?;
                let shape = dims
                    .split('x')
                    .map(|d| {
                        d.parse::<usize>()
                            .map_err(|_| Error::Io(format!("bad dim `{d}` in `{item}`")))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(Spec { shape, dtype: dtype.to_string() })
            })
            .collect()
    };
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 4 {
            return Err(Error::Io(format!("manifest line {}: need 4 columns", i + 1)));
        }
        out.push(Entry {
            name: cols[0].to_string(),
            file: cols[1].to_string(),
            inputs: parse_specs(cols[2])?,
            outputs: parse_specs(cols[3])?,
        });
    }
    Ok(out)
}

/// Canonical real-numerics shapes: the crate's single Rust mirror of
/// `python/compile/model.py` (execases re-exports from here; change the
/// Python side and this module together).
pub mod canonical {
    pub const GEMM_K: usize = 128;
    pub const GEMM_N: usize = 128;
    pub const GEMM_TMS: [usize; 5] = [8, 16, 32, 64, 128];
    pub const ATTN_SQ: usize = 64;
    pub const ATTN_D: usize = 64;
    pub const ATTN_SKS: [usize; 3] = [16, 32, 64];
    pub const FFN_M: usize = 64;
    pub const FFN_D: usize = 128;
    pub const FFN_F: usize = 64;
}

/// The canonical entry set of `python/compile/model.py::entry_points`,
/// synthesized without a manifest (one entry per AOT artifact).
fn canonical_entries() -> Vec<Entry> {
    use canonical::*;
    let mut out = Vec::new();
    let mut push = |name: String, inputs: Vec<Spec>, outputs: Vec<Spec>| {
        let file = format!("{name}.hlo.txt");
        out.push(Entry { name, file, inputs, outputs });
    };
    for tm in GEMM_TMS {
        push(
            format!("gemm_{tm}x{GEMM_K}x{GEMM_N}"),
            vec![Spec::f32(&[tm, GEMM_K]), Spec::f32(&[GEMM_K, GEMM_N])],
            vec![Spec::f32(&[tm, GEMM_N])],
        );
    }
    for sk in ATTN_SKS {
        push(
            format!("attn_step_q{ATTN_SQ}d{ATTN_D}k{sk}"),
            vec![
                Spec::f32(&[ATTN_SQ, ATTN_D]),
                Spec::f32(&[sk, ATTN_D]),
                Spec::f32(&[sk, ATTN_D]),
                Spec::f32(&[ATTN_SQ, ATTN_D]),
                Spec::f32(&[ATTN_SQ]),
                Spec::f32(&[ATTN_SQ]),
            ],
            vec![
                Spec::f32(&[ATTN_SQ, ATTN_D]),
                Spec::f32(&[ATTN_SQ]),
                Spec::f32(&[ATTN_SQ]),
            ],
        );
    }
    push(
        format!("attn_finalize_q{ATTN_SQ}d{ATTN_D}"),
        vec![Spec::f32(&[ATTN_SQ, ATTN_D]), Spec::f32(&[ATTN_SQ])],
        vec![Spec::f32(&[ATTN_SQ, ATTN_D])],
    );
    push(
        format!("ffn_shard_{FFN_M}x{FFN_D}x{FFN_F}"),
        vec![
            Spec::f32(&[FFN_M, FFN_D]),
            Spec::f32(&[FFN_D, FFN_F]),
            Spec::f32(&[FFN_F]),
            Spec::f32(&[FFN_F, FFN_D]),
        ],
        vec![Spec::f32(&[FFN_M, FFN_D])],
    );
    for (r, c) in [(ATTN_SQ, ATTN_D), (FFN_M, FFN_D), (GEMM_TMS[4], GEMM_N)] {
        push(
            format!("add_{r}x{c}"),
            vec![Spec::f32(&[r, c]), Spec::f32(&[r, c])],
            vec![Spec::f32(&[r, c])],
        );
    }
    out
}

enum Backend {
    /// Pure-Rust interpreter of the canonical kernel families.
    HostRef,
    #[cfg(feature = "xla")]
    Pjrt(pjrt::PjrtBackend),
}

/// The artifact runtime (`Send + Sync`; share one per process).
pub struct Runtime {
    entries: HashMap<String, Entry>,
    backend: Backend,
    /// Cumulative number of artifact executions (perf accounting).
    calls: AtomicU64,
}

impl Runtime {
    /// Open an artifacts directory (expects `manifest.tsv`). Executes via
    /// PJRT when the crate is built with the `xla` feature, and via the
    /// host-reference interpreter (validated against the same manifest
    /// specs) otherwise.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = std::fs::read_to_string(dir.join("manifest.tsv")).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {}/manifest.tsv ({e}); run `make artifacts` first",
                dir.display()
            ))
        })?;
        let entries: HashMap<String, Entry> = parse_manifest(&manifest)?
            .into_iter()
            .map(|e| (e.name.clone(), e))
            .collect();
        #[cfg(feature = "xla")]
        let backend = Backend::Pjrt(pjrt::PjrtBackend::new(dir)?);
        #[cfg(not(feature = "xla"))]
        let backend = Backend::HostRef;
        Ok(Runtime { entries, backend, calls: AtomicU64::new(0) })
    }

    /// The host-reference runtime: canonical entries, no artifacts needed.
    pub fn host_reference() -> Self {
        Runtime {
            entries: canonical_entries().into_iter().map(|e| (e.name.clone(), e)).collect(),
            backend: Backend::HostRef,
            calls: AtomicU64::new(0),
        }
    }

    /// Default artifacts location relative to the crate root.
    pub fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// True when `make artifacts` has produced a manifest.
    pub fn artifacts_available() -> bool {
        Self::artifacts_dir().join("manifest.tsv").exists()
    }

    /// Open the default artifacts directory when present; otherwise fall
    /// back to the host-reference backend so a bare checkout still runs
    /// the full execution stack.
    pub fn open_default() -> Result<Self> {
        if Self::artifacts_available() {
            Self::new(&Self::artifacts_dir())
        } else {
            Ok(Self::host_reference())
        }
    }

    /// Which backend executes calls: `"pjrt"` or `"host-ref"`.
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::HostRef => "host-ref",
            #[cfg(feature = "xla")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries.get(name).ok_or_else(|| {
            Error::Runtime(format!(
                "no artifact `{name}` in manifest (have: {:?})",
                self.names()
            ))
        })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn num_calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Execute artifact `name` on f32 inputs; returns one Vec per output.
    ///
    /// Inputs are (data, shape) pairs validated against the manifest.
    pub fn execute(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let entry = self.entry(name)?;
        if inputs.len() != entry.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                entry.inputs.len()
            )));
        }
        for (i, ((data, shape), spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if *shape != spec.shape.as_slice() {
                return Err(Error::Runtime(format!(
                    "{name}: input {i} shape {shape:?} != manifest {:?}",
                    spec.shape
                )));
            }
            if data.len() != spec.elems() {
                return Err(Error::Runtime(format!(
                    "{name}: input {i} has {} elems for shape {shape:?}",
                    data.len()
                )));
            }
        }
        self.calls.fetch_add(1, Ordering::Relaxed);
        let outputs = match &self.backend {
            Backend::HostRef => host_execute(name, inputs)?,
            #[cfg(feature = "xla")]
            Backend::Pjrt(p) => p.execute(entry, inputs)?,
        };
        if outputs.len() != entry.outputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} outputs returned, {} expected",
                outputs.len(),
                entry.outputs.len()
            )));
        }
        for (i, (out, spec)) in outputs.iter().zip(&entry.outputs).enumerate() {
            if out.len() != spec.elems() {
                return Err(Error::Runtime(format!(
                    "{name}: output {i} has {} elems, expected {}",
                    out.len(),
                    spec.elems()
                )));
            }
        }
        Ok(outputs)
    }
}

/// Evaluate one canonical kernel family on the host (shapes are taken from
/// the already-validated inputs, the family from the name prefix).
fn host_execute(name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
    let arity = |n: usize| -> Result<()> {
        if inputs.len() != n {
            return Err(Error::Runtime(format!(
                "{name}: host backend expected {n} inputs, got {}",
                inputs.len()
            )));
        }
        Ok(())
    };
    if name.starts_with("gemm_") {
        arity(2)?;
        let (a, ash) = inputs[0];
        let (b, bsh) = inputs[1];
        let (m, k, n) = (ash[0], ash[1], bsh[1]);
        Ok(vec![host_gemm(a, b, m, k, n)])
    } else if name.starts_with("attn_step_") {
        arity(6)?;
        let (q, qsh) = inputs[0];
        let (k, ksh) = inputs[1];
        let (v, _) = inputs[2];
        let (acc, _) = inputs[3];
        let (m, _) = inputs[4];
        let (l, _) = inputs[5];
        let (sq, d, sk) = (qsh[0], qsh[1], ksh[0]);
        let scale = 1.0 / (d as f32).sqrt();
        let (a2, m2, l2) = host_attn_step(q, k, v, acc, m, l, sq, sk, d, scale);
        Ok(vec![a2, m2, l2])
    } else if name.starts_with("attn_finalize_") {
        arity(2)?;
        let (acc, ash) = inputs[0];
        let (l, _) = inputs[1];
        Ok(vec![host_attn_finalize(acc, l, ash[0], ash[1])])
    } else if name.starts_with("ffn_shard_") {
        arity(4)?;
        let (x, xsh) = inputs[0];
        let (w1, w1sh) = inputs[1];
        let (b1, _) = inputs[2];
        let (w2, _) = inputs[3];
        Ok(vec![host_ffn_shard(x, w1, b1, w2, xsh[0], xsh[1], w1sh[1])])
    } else if name.starts_with("add_") {
        arity(2)?;
        let (x, _) = inputs[0];
        let (y, _) = inputs[1];
        Ok(vec![x.iter().zip(y).map(|(a, b)| a + b).collect()])
    } else {
        Err(Error::Runtime(format!(
            "host reference backend has no rule for artifact `{name}`"
        )))
    }
}

/// PJRT backend (feature `xla`): compile HLO-text artifacts lazily and
/// cache the loaded executables. ALL PJRT access — compile, literal
/// conversion, execute — is serialized behind one `Mutex`: the `xla`
/// crate's wrappers are not documented thread-safe (the pre-refactor
/// runtime kept them behind `Rc`/`RefCell` for a reason), so only one
/// thread touches them at a time. Throughput is unaffected at validation
/// scale because the PJRT CPU client multithreads each computation
/// internally.
#[cfg(feature = "xla")]
mod pjrt {
    use super::*;
    use std::sync::Mutex;

    struct State {
        client: xla::PjRtClient,
        dir: PathBuf,
        cache: HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
    }

    pub(super) struct PjrtBackend {
        state: Mutex<State>,
    }

    // SAFETY: `State` is only ever reached through the Mutex, so every
    // PJRT call is fully serialized — cross-thread access is strictly
    // sequential, never concurrent, and the `Rc`s never leave the guard.
    // This asserts only that the xla wrappers are not thread-AFFINE
    // (usable from a thread other than the creating one), not that they
    // are thread-safe.
    unsafe impl Send for PjrtBackend {}
    unsafe impl Sync for PjrtBackend {}

    impl PjrtBackend {
        pub(super) fn new(dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e:?}")))?;
            Ok(PjrtBackend {
                state: Mutex::new(State {
                    client,
                    dir: dir.to_path_buf(),
                    cache: HashMap::new(),
                }),
            })
        }

        pub(super) fn execute(
            &self,
            entry: &Entry,
            inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<Vec<f32>>> {
            let name = &entry.name;
            let mut state = self.state.lock().unwrap();
            let exe = match state.cache.get(&entry.name) {
                Some(exe) => exe.clone(),
                None => {
                    let path = state.dir.join(&entry.file);
                    let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
                        Error::Runtime(format!("parse {}: {e:?}", path.display()))
                    })?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = state
                        .client
                        .compile(&comp)
                        .map_err(|e| Error::Runtime(format!("compile {name}: {e:?}")))?;
                    let rc = std::rc::Rc::new(exe);
                    state.cache.insert(entry.name.clone(), rc.clone());
                    rc
                }
            };
            let mut literals = Vec::with_capacity(inputs.len());
            for (i, (data, shape)) in inputs.iter().enumerate() {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("{name}: reshape input {i}: {e:?}")))?;
                literals.push(lit);
            }
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("{name}: execute: {e:?}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("{name}: fetch: {e:?}")))?;
            // aot.py lowers with return_tuple=True: output is always a tuple.
            let parts = result
                .to_tuple()
                .map_err(|e| Error::Runtime(format!("{name}: untuple: {e:?}")))?;
            parts
                .into_iter()
                .enumerate()
                .map(|(i, lit)| {
                    lit.to_vec::<f32>()
                        .map_err(|e| Error::Runtime(format!("{name}: output {i}: {e:?}")))
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::verify::{assert_allclose, host_attention};
    use crate::util::Rng;

    #[test]
    fn manifest_parsing() {
        let text = "gemm\tgemm.hlo.txt\t8x128,float32;128x128,float32\t8x128,float32\n\
                    fin\tfin.hlo.txt\t64x64,float32;64,float32\t64x64,float32\n";
        let entries = parse_manifest(text).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].name, "gemm");
        assert_eq!(entries[0].inputs[0].shape, vec![8, 128]);
        assert_eq!(entries[0].inputs[0].elems(), 1024);
        assert_eq!(entries[1].inputs[1].shape, vec![64]);
    }

    #[test]
    fn manifest_errors() {
        assert!(parse_manifest("only\tthree\tcolumns\n").is_err());
        assert!(parse_manifest("a\tb\tbadspec\t8,f32\n").is_err());
        assert!(parse_manifest("a\tb\t8xZ,f32\t8,f32\n").is_err());
        assert!(parse_manifest("").unwrap().is_empty());
    }

    #[test]
    fn host_reference_lists_all_kernel_families() {
        let rt = Runtime::host_reference();
        assert_eq!(rt.backend_name(), "host-ref");
        let names = rt.names();
        assert!(names.iter().any(|n| n.starts_with("gemm_")));
        assert!(names.iter().any(|n| n.starts_with("attn_step_")));
        assert!(names.iter().any(|n| n.starts_with("attn_finalize_")));
        assert!(names.iter().any(|n| n.starts_with("ffn_shard_")));
        assert!(names.iter().any(|n| n.starts_with("add_")));
        assert_eq!(names.len(), 13, "{names:?}"); // mirror of model.py entry_points
    }

    #[test]
    fn host_reference_gemm_matches_oracle() {
        let rt = Runtime::host_reference();
        let mut rng = Rng::new(11);
        let a = rng.vec_f32(8 * 128);
        let b = rng.vec_f32(128 * 128);
        let outs = rt.execute("gemm_8x128x128", &[(&a, &[8, 128]), (&b, &[128, 128])]).unwrap();
        let want = crate::exec::verify::host_gemm(&a, &b, 8, 128, 128);
        assert_eq!(outs[0], want);
    }

    #[test]
    fn host_reference_attention_chain() {
        // chain attn_step over 2 chunks + finalize == full attention
        let rt = Runtime::host_reference();
        let mut rng = Rng::new(21);
        let (sq, d) = (64usize, 64usize);
        let q = rng.vec_f32(sq * d);
        let k = rng.vec_f32(2 * sq * d);
        let v = rng.vec_f32(2 * sq * d);
        let mut acc = vec![0.0f32; sq * d];
        let mut m = vec![-1e30f32; sq];
        let mut l = vec![0.0f32; sq];
        for c in 0..2 {
            let ks = &k[c * sq * d..(c + 1) * sq * d];
            let vs = &v[c * sq * d..(c + 1) * sq * d];
            let outs = rt
                .execute(
                    "attn_step_q64d64k64",
                    &[
                        (&q, &[sq, d]),
                        (ks, &[sq, d]),
                        (vs, &[sq, d]),
                        (&acc, &[sq, d]),
                        (&m, &[sq]),
                        (&l, &[sq]),
                    ],
                )
                .unwrap();
            acc = outs[0].clone();
            m = outs[1].clone();
            l = outs[2].clone();
        }
        let o = rt.execute("attn_finalize_q64d64", &[(&acc, &[sq, d]), (&l, &[sq])]).unwrap();
        let want = host_attention(&q, &k, &v, sq, 2 * sq, d, 1.0 / (d as f32).sqrt());
        assert_allclose(&o[0], &want, 1e-4, 1e-4, "host chain").unwrap();
    }

    #[test]
    fn shape_and_arity_validation() {
        let rt = Runtime::host_reference();
        let a = vec![0.0f32; 8 * 128];
        let b = vec![0.0f32; 128 * 128];
        // wrong arity
        assert!(rt.execute("gemm_8x128x128", &[(&a, &[8, 128])]).is_err());
        // wrong shape
        assert!(rt
            .execute("gemm_8x128x128", &[(&a, &[128, 8]), (&b, &[128, 128])])
            .is_err());
        // wrong data length
        assert!(rt
            .execute("gemm_8x128x128", &[(&a[..10], &[8, 128]), (&b, &[128, 128])])
            .is_err());
        // unknown artifact
        assert!(rt.execute("nope", &[]).is_err());
    }

    #[test]
    fn call_accounting_is_atomic() {
        let rt = Runtime::host_reference();
        assert_eq!(rt.num_calls(), 0);
        let x = vec![1.0f32; 64 * 64];
        std::thread::scope(|s| {
            for _ in 0..4 {
                let rt = &rt;
                let x = &x;
                s.spawn(move || {
                    for _ in 0..5 {
                        rt.execute("add_64x64", &[(x, &[64, 64]), (x, &[64, 64])]).unwrap();
                    }
                });
            }
        });
        assert_eq!(rt.num_calls(), 20);
    }

    #[test]
    fn open_default_never_fails_on_bare_checkout() {
        // with artifacts: manifest-backed; without: host reference — either
        // way the execution stack has a working runtime
        let rt = Runtime::open_default().unwrap();
        assert!(!rt.names().is_empty());
    }
}
