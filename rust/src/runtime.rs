//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the only place the Rust side touches XLA. Artifacts are produced
//! once by `make artifacts` (python/compile/aot.py) and listed in
//! `artifacts/manifest.tsv`; at startup we parse the manifest, and compile
//! each HLO module lazily on first use (compiled executables are cached).
//!
//! Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits
//! 64-bit instruction ids that the crate's xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Spec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl Spec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<Spec>,
    pub outputs: Vec<Spec>,
}

/// Parse `manifest.tsv` (written by aot.py alongside manifest.json).
pub fn parse_manifest(text: &str) -> Result<Vec<Entry>> {
    let parse_specs = |s: &str| -> Result<Vec<Spec>> {
        if s.is_empty() {
            return Ok(vec![]);
        }
        s.split(';')
            .map(|item| {
                let (dims, dtype) = item
                    .split_once(',')
                    .ok_or_else(|| Error::Io(format!("bad spec `{item}`")))?;
                let shape = dims
                    .split('x')
                    .map(|d| {
                        d.parse::<usize>()
                            .map_err(|_| Error::Io(format!("bad dim `{d}` in `{item}`")))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(Spec { shape, dtype: dtype.to_string() })
            })
            .collect()
    };
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 4 {
            return Err(Error::Io(format!("manifest line {}: need 4 columns", i + 1)));
        }
        out.push(Entry {
            name: cols[0].to_string(),
            file: cols[1].to_string(),
            inputs: parse_specs(cols[2])?,
            outputs: parse_specs(cols[3])?,
        });
    }
    Ok(out)
}

/// The PJRT-backed artifact runtime.
///
/// Not `Sync`: the exec engine is a single-threaded cooperative interpreter
/// by design (deterministic; see `exec::`), so one runtime per process is
/// enough. The PJRT CPU client itself multithreads the compute internally.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    entries: HashMap<String, Entry>,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative number of artifact executions (perf accounting).
    calls: RefCell<u64>,
}

impl Runtime {
    /// Open the artifacts directory (expects `manifest.tsv`).
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = std::fs::read_to_string(dir.join("manifest.tsv")).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {}/manifest.tsv ({e}); run `make artifacts` first",
                dir.display()
            ))
        })?;
        let entries = parse_manifest(&manifest)?
            .into_iter()
            .map(|e| (e.name.clone(), e))
            .collect();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e:?}")))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            entries,
            cache: RefCell::new(HashMap::new()),
            calls: RefCell::new(0),
        })
    }

    /// Default artifacts location relative to the crate root.
    pub fn open_default() -> Result<Self> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Self::new(&dir)
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries.get(name).ok_or_else(|| {
            Error::Runtime(format!(
                "no artifact `{name}` in manifest (have: {:?})",
                self.entries.keys().collect::<Vec<_>>()
            ))
        })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn num_calls(&self) -> u64 {
        *self.calls.borrow()
    }

    fn load(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.entry(name)?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| Error::Runtime(format!("parse {}: {e:?}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {name}: {e:?}")))?;
        let rc = std::rc::Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Execute artifact `name` on f32 inputs; returns one Vec per output.
    ///
    /// Inputs are (data, shape) pairs validated against the manifest.
    pub fn execute(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let entry = self.entry(name)?.clone();
        if inputs.len() != entry.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                entry.inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, ((data, shape), spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if *shape != spec.shape.as_slice() {
                return Err(Error::Runtime(format!(
                    "{name}: input {i} shape {shape:?} != manifest {:?}",
                    spec.shape
                )));
            }
            if data.len() != spec.elems() {
                return Err(Error::Runtime(format!(
                    "{name}: input {i} has {} elems for shape {shape:?}",
                    data.len()
                )));
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| Error::Runtime(format!("{name}: reshape input {i}: {e:?}")))?;
            literals.push(lit);
        }
        let exe = self.load(name)?;
        *self.calls.borrow_mut() += 1;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("{name}: execute: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("{name}: fetch: {e:?}")))?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("{name}: untuple: {e:?}")))?;
        if parts.len() != entry.outputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} outputs returned, {} expected",
                parts.len(),
                entry.outputs.len()
            )));
        }
        parts
            .into_iter()
            .enumerate()
            .map(|(i, lit)| {
                let v = lit
                    .to_vec::<f32>()
                    .map_err(|e| Error::Runtime(format!("{name}: output {i}: {e:?}")))?;
                if v.len() != entry.outputs[i].elems() {
                    return Err(Error::Runtime(format!(
                        "{name}: output {i} has {} elems, expected {}",
                        v.len(),
                        entry.outputs[i].elems()
                    )));
                }
                Ok(v)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let text = "gemm\tgemm.hlo.txt\t8x128,float32;128x128,float32\t8x128,float32\n\
                    fin\tfin.hlo.txt\t64x64,float32;64,float32\t64x64,float32\n";
        let entries = parse_manifest(text).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].name, "gemm");
        assert_eq!(entries[0].inputs[0].shape, vec![8, 128]);
        assert_eq!(entries[0].inputs[0].elems(), 1024);
        assert_eq!(entries[1].inputs[1].shape, vec![64]);
    }

    #[test]
    fn manifest_errors() {
        assert!(parse_manifest("only\tthree\tcolumns\n").is_err());
        assert!(parse_manifest("a\tb\tbadspec\t8,f32\n").is_err());
        assert!(parse_manifest("a\tb\t8xZ,f32\t8,f32\n").is_err());
        assert!(parse_manifest("").unwrap().is_empty());
    }

    // Executing real artifacts requires `make artifacts` + the PJRT client;
    // covered by rust/tests/integration_runtime.rs so `cargo test --lib`
    // stays artifact-free.
}
