//! Bench: regenerate **Fig. 9** — distributed attention operators (HP, SP,
//! RingAttention) across sequence lengths, Syncopate vs baselines.
//!
//! Run: `cargo bench --bench fig9_attention`

use std::time::Instant;

use syncopate::autotune::Budget;
use syncopate::reports;

fn main() {
    let budget =
        if std::env::var("FIG_FULL").is_ok() { Budget::Full } else { Budget::Quick };
    let t0 = Instant::now();
    let t = reports::fig9(budget).expect("fig9");
    println!("{}", t.render());
    for base in reports::SYSTEMS.iter().skip(1) {
        if let (Some(avg), Some(max)) =
            (t.geomean_ratio("syncopate", base), t.max_ratio("syncopate", base))
        {
            println!("  syncopate vs {base:15} avg {avg:.2}x  max {max:.2}x");
        }
    }
    println!("\n[fig9 regenerated in {:.1}s]", t0.elapsed().as_secs_f64());
}
