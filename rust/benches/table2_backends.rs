//! Bench: regenerate **Table 2** (communication mechanism comparison) and
//! microbenchmark the backend model itself.
//!
//! Run: `cargo bench --bench table2_backends`

use std::time::Instant;

use syncopate::backend::{self, BackendKind};
use syncopate::reports;

fn main() {
    println!("{}", reports::table2().render());

    // model-throughput microbench: transfer_time_us evaluations/sec (the
    // autotuner calls this in its inner loop)
    let topo = syncopate::hw::catalog::topology("h100_node", 8).unwrap();
    let t0 = Instant::now();
    let mut acc = 0.0f64;
    let n = 2_000_000usize;
    for i in 0..n {
        let bytes = 1024 << (i % 18);
        acc += backend::transfer_time_us(BackendKind::CopyEngine, bytes, 1, 0, topo.intra);
        acc += backend::transfer_time_us(BackendKind::TmaSpecialized, bytes, 1, 16, topo.intra);
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "model microbench: {:.1}M transfer_time evals/sec (checksum {acc:.1})",
        2.0 * n as f64 / dt / 1e6
    );
}
