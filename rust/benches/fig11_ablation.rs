//! Bench: regenerate **Fig. 11** — ablation and sensitivity studies over
//! Syncopate's tuning space, plus the two extra design-choice ablations
//! DESIGN.md §10 calls out (swizzle-vs-reorder and minimal-vs-barrier sync).
//!
//! (a) communication backend selection for a fixed logical schedule
//! (b) chunk size (split factor) sensitivity — non-monotone, interior peak
//! (c) SM allocation sweet spot
//! (d) intra-tile scheduling spread
//!
//! Run: `cargo bench --bench fig11_ablation`

use syncopate::baselines::{self, Baseline};
use syncopate::coordinator::operators::{compile_operator, compile_operator_barrier_sync};
use syncopate::coordinator::TuneConfig;
use syncopate::metrics::Table;
use syncopate::reports;
use syncopate::sim::engine::simulate;
use syncopate::util::fmt_us;
use syncopate::workload::{OpKind, OperatorInstance, LLAMA3_70B, LLAMA3_8B};

fn main() {
    println!("{}", reports::fig11a().expect("11a").render());
    println!("{}", reports::fig11b().expect("11b").render());
    println!("{}", reports::fig11c().expect("11c").render());
    println!("{}", reports::fig11d().expect("11d").render());

    // --- ablation: scheduler swizzle vs explicit reorder pass (Fig. 6) ----
    let topo = syncopate::hw::catalog::topology("h100_node", 8).unwrap();
    let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, 8192, 8);
    let mut t = Table::new(
        "Ablation: swizzle-in-scheduler (Fig 6c) vs reorder pass (Fig 6b)",
        &["latency us"],
        "us",
    );
    let (sp, spar) = compile_operator(&op, &TuneConfig::default(), &topo).unwrap();
    t.push_row("syncopate swizzle", vec![simulate(&sp, &topo, spar).unwrap().makespan_us]);
    let (fp, fpar) = baselines::plan(Baseline::FlashOverlap, &op, &topo).unwrap();
    t.push_row("reorder pass (flashoverlap-style)", vec![
        simulate(&fp, &topo, fpar).unwrap().makespan_us,
    ]);
    println!("{}", t.render());

    // --- ablation: minimal sync insertion vs conservative barrier ---------
    let mut t2 = Table::new(
        "Ablation: minimal sync vs barrier-per-kernel",
        &["makespan us", "exposed comm us"],
        "us",
    );
    for (label, op) in [
        ("ag-gemm-70b", OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, 8192, 8)),
        ("ring-attn-8b", OperatorInstance::attention(OpKind::RingAttn, &LLAMA3_8B, 16384, 8)),
    ] {
        let cfg = TuneConfig { split: 1, ..TuneConfig::default() };
        let (p1, params) = compile_operator(&op, &cfg, &topo).unwrap();
        let r1 = simulate(&p1, &topo, params).unwrap();
        let (p2, _) = compile_operator_barrier_sync(&op, &cfg, &topo).unwrap();
        let r2 = simulate(&p2, &topo, params).unwrap();
        t2.push_row(&format!("{label} minimal"), vec![r1.makespan_us, r1.exposed_wait_us]);
        t2.push_row(&format!("{label} barrier"), vec![r2.makespan_us, r2.exposed_wait_us]);
        println!(
            "  {label}: minimal sync hides {} more communication",
            fmt_us(r2.exposed_wait_us - r1.exposed_wait_us)
        );
    }
    println!("{}", t2.render());
}
