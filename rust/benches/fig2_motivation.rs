//! Bench: regenerate **Fig. 2** — the motivation microbenchmarks.
//!
//! (a) SM utilization vs GEMM size × tile config (wave quantization)
//! (b) streamed persistent kernel vs kernel-partitioned launches
//! (c) bandwidth vs transfer size per backend
//! (d) bandwidth vs #communication SMs per backend
//!
//! Run: `cargo bench --bench fig2_motivation`

use syncopate::reports;

fn main() {
    println!("{}", reports::fig2a().render());
    println!("{}", reports::fig2b().expect("fig2b").render());
    println!("{}", reports::fig2c().render());
    println!("{}", reports::fig2d().render());
}
