//! Bench: regenerate **Fig. 10** — integration with higher-level
//! distributed compilers: Domino/Alpa partition IRs and Mercury's loop IR
//! lowered through Syncopate's chunk representation, native kernel-level
//! execution vs fine-grained regeneration, plus the three collective
//! lowering paths (direct | template | synth).
//!
//! Run: `cargo bench --bench fig10_integration`

use syncopate::autotune::Budget;
use syncopate::reports;

fn main() {
    let t = reports::fig10(Budget::Quick).expect("fig10");
    println!("{}", t.render());
    for (label, row) in &t.rows {
        println!("  {label}: +syncopate speedup {:.2}x over native", row[0] / row[1]);
    }
}
