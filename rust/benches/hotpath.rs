//! Bench: L3 hot-path microbenchmarks (the §Perf numbers of EXPERIMENTS.md).
//!
//! Measures the throughput of the request-path components the coordinator
//! exercises per served operator: compilation, sync planning, simulation
//! (event engine), and a full quick autotune. Targets (DESIGN.md §9):
//! simulate a full 8-rank fig8 config in <10 ms; autotune an operator <1 s.
//!
//! Run: `cargo bench --bench hotpath`

use std::time::Instant;

use syncopate::autotune::{self, Budget};
use syncopate::coordinator::execases;
use syncopate::coordinator::operators::compile_operator;
use syncopate::coordinator::TuneConfig;
use syncopate::exec::{prepare, run_prepared, ExecOptions};
use syncopate::runtime::Runtime;
use syncopate::sim::engine::simulate;
use syncopate::topo::Topology;
use syncopate::workload::{OpKind, OperatorInstance, LLAMA3_70B};

fn bench<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "{label:48} {:>10.3} ms/iter   {:>8.1} iters/s",
        per * 1e3,
        1.0 / per
    );
    per
}

fn main() {
    let topo = Topology::h100_node(8).unwrap();
    let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, 8192, 8);
    let cfg = TuneConfig::default();

    println!("== L3 hot paths (8-rank llama3-70b AG-GEMM) ==");
    let compile_ms = bench("compile_operator (schedule+sync+codegen)", 50, || {
        let _ = compile_operator(&op, &cfg, &topo).unwrap();
    });

    let (plan, params) = compile_operator(&op, &cfg, &topo).unwrap();
    let sim_ms = bench("simulate (event engine, full plan)", 200, || {
        let _ = simulate(&plan, &topo, params).unwrap();
    });

    let split8 = TuneConfig { split: 8, ..cfg.clone() };
    let (plan8, params8) = compile_operator(&op, &split8, &topo).unwrap();
    println!(
        "  plan sizes: split2 {} transfers, split8 {} transfers",
        plan.total_transfers(),
        plan8.total_transfers()
    );
    bench("simulate (split 8: 4x transfers)", 200, || {
        let _ = simulate(&plan8, &topo, params8).unwrap();
    });

    let tune_s = bench("autotune quick (full knob sweep)", 3, || {
        let _ = autotune::tune(&op, &topo, Budget::Quick).unwrap();
    });

    let attn = OperatorInstance::attention(OpKind::RingAttn, &LLAMA3_70B, 32768, 8);
    bench("autotune quick (ring attention 32k)", 3, || {
        let _ = autotune::tune(&attn, &topo, Budget::Quick).unwrap();
    });

    println!("\ntargets: simulate < 10 ms ({}), tune < 1 s ({})",
        if sim_ms * 1e3 < 10.0 { "MET" } else { "MISSED" },
        if tune_s < 1.0 { "MET" } else { "MISSED" },
    );
    let _ = compile_ms;

    // -- executor engines: sequential reference vs parallel per-rank ------
    // Real-numerics AG-GEMM (split 2) per world size. The case is built
    // once outside the timed region (AG-GEMM execution is idempotent over
    // the store: gathers and outputs are plain overwrites), so the loop
    // times exactly the engine: transfers, signals, kernel calls.
    let rt = Runtime::open_default().expect("host-ref fallback cannot fail");
    println!("\n== exec engine: sequential vs parallel (runtime backend: {}) ==",
        rt.backend_name());
    for world in [2usize, 4, 8] {
        let case = execases::ag_gemm(world, 2, 7).unwrap();
        // tune-once, run-many: prepare the plan once, time only execution
        let prep = prepare(&case.plan, &case.sched.tensors).unwrap();
        let mut per_mode = [0.0f64; 2];
        for (mi, opts) in [ExecOptions::sequential(), ExecOptions::parallel()]
            .into_iter()
            .enumerate()
        {
            let label = format!(
                "exec ag-gemm w{world} s2 ({})",
                if mi == 0 { "sequential" } else { "parallel" }
            );
            per_mode[mi] = bench(&label, 5, || {
                let _ = run_prepared(&prep, &case.store, &rt, &opts).unwrap();
            });
        }
        println!(
            "  world {world}: parallel speedup over sequential {:.2}x (seq {:.3} ms, par {:.3} ms)",
            per_mode[0] / per_mode[1],
            per_mode[0] * 1e3,
            per_mode[1] * 1e3
        );
    }
}
