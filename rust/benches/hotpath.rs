//! Bench: L3 hot-path microbenchmarks (the §Perf numbers of EXPERIMENTS.md).
//!
//! Measures the throughput of the request-path components the coordinator
//! exercises per served operator: compilation, sync planning, simulation
//! (event engine), and a full quick autotune. Targets (DESIGN.md §9):
//! simulate a full 8-rank fig8 config in <10 ms; autotune an operator <1 s.
//!
//! Run: `cargo bench --bench hotpath` (or `make bench`).
//!
//! Besides the human-readable stdout table, every measurement is APPENDED
//! as one `syncopate.bench.v1` row to `BENCH_results.json` at the
//! repository root (override the path with the `BENCH_RESULTS` env var) —
//! the same append-only trajectory `perf record` and `exec --repeat
//! --bench` feed, so the perf history accumulates across commits instead
//! of being overwritten per run.

use std::time::Instant;

use syncopate::autotune::{self, Budget};
use syncopate::coordinator::execases;
use syncopate::coordinator::operators::compile_operator;
use syncopate::coordinator::TuneConfig;
use syncopate::exec::{
    prepare, run_prepared, run_prepared_reusing, run_prepared_traced, ExecOptions, PlanArena,
    SyncStrategy,
};
use syncopate::runtime::Runtime;
use syncopate::sim::engine::simulate;
use syncopate::workload::{OpKind, OperatorInstance, LLAMA3_70B};

/// Collected measurements: (label, seconds per iteration).
struct Results(Vec<(String, f64)>);

impl Results {
    fn bench<F: FnMut()>(&mut self, label: &str, iters: usize, mut f: F) -> f64 {
        // warmup
        f();
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "{label:48} {:>10.3} ms/iter   {:>8.1} iters/s",
            per * 1e3,
            1.0 / per
        );
        self.0.push((label.to_string(), per));
        per
    }

    fn write(&self) {
        // cargo bench runs with cwd = rust/; the default lands the file at
        // the repository root next to ROADMAP.md
        let path = std::env::var("BENCH_RESULTS")
            .unwrap_or_else(|_| "../BENCH_results.json".to_string());
        for (label, per) in &self.0 {
            let row = syncopate::perf::bench_row(
                "hotpath",
                &[("label", label.as_str())],
                &[("ms_per_iter", per * 1e3), ("iters_per_s", 1.0 / per)],
            );
            if let Err(e) = syncopate::perf::append_bench_row(&path, &row) {
                eprintln!("\ncould not append to {path}: {e}");
                return;
            }
        }
        println!("\n{} trajectory rows -> {path}", self.0.len());
    }
}

fn main() {
    let mut res = Results(Vec::new());
    let topo = syncopate::hw::catalog::topology("h100_node", 8).unwrap();
    let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, 8192, 8);
    let cfg = TuneConfig::default();

    println!("== L3 hot paths (8-rank llama3-70b AG-GEMM) ==");
    let compile_ms = res.bench("compile_operator (schedule+sync+codegen)", 50, || {
        let _ = compile_operator(&op, &cfg, &topo).unwrap();
    });

    let (plan, params) = compile_operator(&op, &cfg, &topo).unwrap();
    let sim_ms = res.bench("simulate (event engine, full plan)", 200, || {
        let _ = simulate(&plan, &topo, params).unwrap();
    });

    let split8 = TuneConfig { split: 8, ..cfg.clone() };
    let (plan8, params8) = compile_operator(&op, &split8, &topo).unwrap();
    println!(
        "  plan sizes: split2 {} transfers, split8 {} transfers",
        plan.total_transfers(),
        plan8.total_transfers()
    );
    res.bench("simulate (split 8: 4x transfers)", 200, || {
        let _ = simulate(&plan8, &topo, params8).unwrap();
    });

    let tune_s = res.bench("autotune quick (full knob sweep)", 3, || {
        let _ = autotune::tune(&op, &topo, Budget::Quick).unwrap();
    });

    let attn = OperatorInstance::attention(OpKind::RingAttn, &LLAMA3_70B, 32768, 8);
    res.bench("autotune quick (ring attention 32k)", 3, || {
        let _ = autotune::tune(&attn, &topo, Budget::Quick).unwrap();
    });

    println!("\ntargets: simulate < 10 ms ({}), tune < 1 s ({})",
        if sim_ms * 1e3 < 10.0 { "MET" } else { "MISSED" },
        if tune_s < 1.0 { "MET" } else { "MISSED" },
    );
    let _ = compile_ms;

    // -- executor engines: sequential reference vs parallel per-rank ------
    // Real-numerics AG-GEMM (split 2) per world size. The case is built
    // once outside the timed region (AG-GEMM execution is idempotent over
    // the store: gathers and outputs are plain overwrites), so the loop
    // times exactly the engine: transfers, signals, kernel calls.
    let rt = Runtime::open_default().expect("host-ref fallback cannot fail");
    println!("\n== exec engine: sequential vs parallel (runtime backend: {}) ==",
        rt.backend_name());
    for world in [2usize, 4, 8] {
        let case = execases::ag_gemm(world, 2, 7).unwrap();
        // tune-once, run-many: prepare the plan once, time only execution
        let prep = prepare(&case.plan, &case.sched.tensors).unwrap();
        let mut per_mode = [0.0f64; 2];
        for (mi, opts) in [ExecOptions::sequential(), ExecOptions::parallel()]
            .into_iter()
            .enumerate()
        {
            let label = format!(
                "exec ag-gemm w{world} s2 ({})",
                if mi == 0 { "sequential" } else { "parallel" }
            );
            per_mode[mi] = res.bench(&label, 5, || {
                let _ = run_prepared(&prep, &case.store, &rt, &opts).unwrap();
            });
        }
        println!(
            "  world {world}: parallel speedup over sequential {:.2}x (seq {:.3} ms, par {:.3} ms)",
            per_mode[0] / per_mode[1],
            per_mode[0] * 1e3,
            per_mode[1] * 1e3
        );
    }

    // -- tracing overhead: trace-off vs trace-on on the same prepared plan.
    // Trace-off IS the pre-tracing hot path (run_prepared carries a None
    // sink internally: one dead branch per op) — the acceptance bar is
    // that these two "off" rows match the historical numbers, with the
    // "on" rows quantifying what capture costs when explicitly requested.
    println!("\n== exec tracing: off (production path) vs on (capture) ==");
    {
        let case = execases::ag_gemm(4, 2, 7).unwrap();
        let prep = prepare(&case.plan, &case.sched.tensors).unwrap();
        for (mode_label, opts) in
            [("sequential", ExecOptions::sequential()), ("parallel", ExecOptions::parallel())]
        {
            let off = res.bench(&format!("exec ag-gemm w4 s2 {mode_label} trace-off"), 5, || {
                let _ = run_prepared(&prep, &case.store, &rt, &opts).unwrap();
            });
            let on = res.bench(&format!("exec ag-gemm w4 s2 {mode_label} trace-on"), 5, || {
                let _ = run_prepared_traced(&prep, &case.store, &rt, &opts).unwrap();
            });
            println!(
                "  {mode_label}: tracing overhead {:+.1}% (off {:.3} ms, on {:.3} ms)",
                (on / off - 1.0) * 100.0,
                off * 1e3,
                on * 1e3
            );
        }
    }

    // -- synchronization cores: retained condvar baseline vs the lock-free
    // atomic hot path, plus the arena-reuse entry point (zero allocation
    // after the first run). Trace-off parallel, the production path.
    println!("\n== parallel sync core: condvar baseline vs atomic (trace-off) ==");
    for world in [2usize, 4, 8] {
        let case = execases::ag_gemm(world, 2, 7).unwrap();
        let prep = prepare(&case.plan, &case.sched.tensors).unwrap();
        let condvar_opts =
            ExecOptions { sync: SyncStrategy::Condvar, ..ExecOptions::parallel() };
        let atomic_opts = ExecOptions::parallel();
        let condvar =
            res.bench(&format!("exec ag-gemm w{world} s2 parallel condvar"), 10, || {
                let _ = run_prepared(&prep, &case.store, &rt, &condvar_opts).unwrap();
            });
        let atomic =
            res.bench(&format!("exec ag-gemm w{world} s2 parallel atomic"), 10, || {
                let _ = run_prepared(&prep, &case.store, &rt, &atomic_opts).unwrap();
            });
        let mut arena = PlanArena::new(&prep);
        let reused =
            res.bench(&format!("exec ag-gemm w{world} s2 parallel atomic+arena"), 10, || {
                let _ =
                    run_prepared_reusing(&prep, &mut arena, &case.store, &rt, &atomic_opts)
                        .unwrap();
            });
        println!(
            "  world {world}: atomic speedup over condvar {:.2}x (condvar {:.3} ms, \
             atomic {:.3} ms, atomic+arena {:.3} ms)",
            condvar / atomic,
            condvar * 1e3,
            atomic * 1e3,
            reused * 1e3
        );
    }

    // -- observability overhead: the hot-path counters (parks, unparks,
    // queue drains, seen short-circuits, arena reuses) are strictly-Relaxed
    // atomics behind a runtime enable flag; one binary measures both sides
    // of that flag on the same prepared plan. Built with `--features
    // no-obs` the record functions are compiled-out no-ops and the two
    // rows must collapse onto each other.
    println!("\n== hot-path observability: obs-on vs obs-off (parallel atomic) ==");
    {
        let case = execases::ag_gemm(4, 2, 7).unwrap();
        let prep = prepare(&case.plan, &case.sched.tensors).unwrap();
        let opts = ExecOptions::parallel();
        let mut arena = PlanArena::new(&prep);
        syncopate::obs::hot::set_enabled(true);
        let on = res.bench("exec ag-gemm w4 s2 parallel atomic obs-on", 10, || {
            let _ = run_prepared_reusing(&prep, &mut arena, &case.store, &rt, &opts).unwrap();
        });
        syncopate::obs::hot::set_enabled(false);
        let off = res.bench("exec ag-gemm w4 s2 parallel atomic obs-off", 10, || {
            let _ = run_prepared_reusing(&prep, &mut arena, &case.store, &rt, &opts).unwrap();
        });
        syncopate::obs::hot::set_enabled(true);
        println!(
            "  obs overhead {:+.1}% (on {:.3} ms, off {:.3} ms)",
            (on / off - 1.0) * 100.0,
            on * 1e3,
            off * 1e3
        );
    }

    // -- flight recorder overhead: the seqlock ring writes (op issue/apply,
    // signal set/wait, park/unpark, queue drains) sit on the same hot path
    // behind their own runtime flag; measured per world size because the
    // event rate scales with rank count. Under `--features no-obs` the
    // record functions are compiled-out and the rows must collapse.
    println!("\n== flight recorder: flight-on vs flight-off (parallel atomic) ==");
    for world in [2usize, 4, 8] {
        let case = execases::ag_gemm(world, 2, 7).unwrap();
        let prep = prepare(&case.plan, &case.sched.tensors).unwrap();
        let opts = ExecOptions::parallel();
        let mut arena = PlanArena::new(&prep);
        syncopate::obs::flight::set_enabled(true);
        let on = res.bench(&format!("exec ag-gemm w{world} s2 parallel atomic flight-on"), 10, || {
            let _ = run_prepared_reusing(&prep, &mut arena, &case.store, &rt, &opts).unwrap();
        });
        syncopate::obs::flight::set_enabled(false);
        let off =
            res.bench(&format!("exec ag-gemm w{world} s2 parallel atomic flight-off"), 10, || {
                let _ = run_prepared_reusing(&prep, &mut arena, &case.store, &rt, &opts).unwrap();
            });
        syncopate::obs::flight::set_enabled(true);
        println!(
            "  world {world}: flight overhead {:+.1}% (on {:.3} ms, off {:.3} ms)",
            (on / off - 1.0) * 100.0,
            on * 1e3,
            off * 1e3
        );
    }

    res.write();
}
