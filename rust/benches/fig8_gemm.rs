//! Bench: regenerate **Fig. 8** — distributed GEMM operators (AG-GEMM,
//! GEMM-RS, GEMM-AR) across Llama-3/Qwen shapes on 4- and 8-GPU meshes,
//! Syncopate (autotuned) vs all baselines.
//!
//! Run: `cargo bench --bench fig8_gemm` (add `--full` via env FIG_FULL=1 for
//! the full tuning budget)

use std::time::Instant;

use syncopate::autotune::Budget;
use syncopate::reports;

fn main() {
    let budget =
        if std::env::var("FIG_FULL").is_ok() { Budget::Full } else { Budget::Quick };
    let t0 = Instant::now();
    let t = reports::fig8(budget).expect("fig8");
    println!("{}", t.render());
    for base in reports::SYSTEMS.iter().skip(1) {
        if let (Some(avg), Some(max)) =
            (t.geomean_ratio("syncopate", base), t.max_ratio("syncopate", base))
        {
            println!("  syncopate vs {base:15} avg {avg:.2}x  max {max:.2}x");
        }
    }
    // supplement: scalability/portability sweep (§6.1 device-count study)
    let s = reports::scalability(budget).expect("scalability");
    println!("\n{}", s.render());
    println!("[fig8 + scalability regenerated in {:.1}s]", t0.elapsed().as_secs_f64());
}
