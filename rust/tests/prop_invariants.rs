//! Property-based tests over randomized inputs.
//!
//! The offline build has no proptest; properties are exercised with the
//! crate's deterministic [`Rng`] over many seeded iterations, with the seed
//! printed on failure so any counterexample reproduces exactly.

use std::collections::HashSet;

use syncopate::chunk::{Chunk, DType, Region, TensorTable};
use syncopate::codegen::Realization;
use syncopate::coordinator::execases::{self, run_and_verify};
use syncopate::coordinator::operators::compile_operator;
use syncopate::coordinator::TuneConfig;
use syncopate::backend::BackendKind;
use syncopate::kernel::grid::{Axis, TileGrid};
use syncopate::kernel::scheduler::{IntraOrder, TileScheduler};
use syncopate::runtime::Runtime;
use syncopate::schedule::validate::{check_covers, topo_order, validate};
use syncopate::schedule::{CommOp, CommSchedule, Dep, TransferKind};
use syncopate::sim::engine::simulate;
use syncopate::util::Rng;
use syncopate::workload::{OpKind, OperatorInstance, LLAMA3_8B};

const ITERS: usize = 60;

/// Property: Region::split partitions exactly — coverage + element count.
#[test]
fn prop_region_split_partitions() {
    let mut rng = Rng::new(0xA11CE);
    for it in 0..ITERS {
        let rows = (rng.below(16) + 1) * 4;
        let cols = rng.below(64) + 1;
        let r = Region::full(&[rows, cols]);
        let n = [1usize, 2, 4][rng.below(3)];
        let parts = r.split(0, n).unwrap_or_else(|e| panic!("iter {it}: {e}"));
        assert!(check_covers(&[rows, cols], &parts), "iter {it}");
        assert_eq!(parts.iter().map(|p| p.elems()).sum::<usize>(), r.elems(), "iter {it}");
    }
}

/// Property: linear_offsets are unique, in-bounds, and count == elems.
#[test]
fn prop_region_offsets_bijective() {
    let mut rng = Rng::new(0xB0B);
    for it in 0..ITERS {
        let shape = [rng.below(6) + 2, rng.below(6) + 2, rng.below(4) + 1];
        let off = [rng.below(shape[0]), rng.below(shape[1]), rng.below(shape[2])];
        let sz = [
            rng.below(shape[0] - off[0]) + 1,
            rng.below(shape[1] - off[1]) + 1,
            rng.below(shape[2] - off[2]) + 1,
        ];
        let r = Region::new(off.to_vec(), sz.to_vec());
        let offs = r.linear_offsets(&shape);
        assert_eq!(offs.len(), r.elems(), "iter {it}");
        let set: HashSet<usize> = offs.iter().copied().collect();
        assert_eq!(set.len(), offs.len(), "iter {it}: duplicate offsets");
        let total: usize = shape.iter().product();
        assert!(offs.iter().all(|&o| o < total), "iter {it}");
    }
}

/// Property: grid coords <-> linear are mutually inverse for random grids.
#[test]
fn prop_grid_coords_roundtrip() {
    let mut rng = Rng::new(0xC0FFEE);
    for it in 0..ITERS {
        let axes = (0..rng.below(3) + 1)
            .map(|i| {
                Axis::new(
                    &format!("A{i}"),
                    rng.below(200) + 1,
                    rng.below(32) + 1,
                )
                .unwrap()
            })
            .collect();
        let g = TileGrid::new(axes).unwrap();
        for _ in 0..10 {
            let id = rng.below(g.num_tiles());
            let c = g.coords(id).unwrap();
            assert_eq!(g.linear(&c).unwrap(), id, "iter {it}");
        }
    }
}

/// Property: every random valid push/pull schedule is accepted by validate
/// and its topo order respects all deps. Duplicate writes of the same shard
/// to the same destination are chained through a dependency on the previous
/// writer — validate() rejects unordered overlapping writes as races.
#[test]
fn prop_random_schedules_validate_and_order() {
    let mut rng = Rng::new(0xDEAD);
    for it in 0..ITERS {
        let world = rng.below(6) + 2;
        let mut table = TensorTable::new();
        let rows = world * (rng.below(4) + 1) * 2;
        let x = table.declare("x", &[rows, 8], DType::F32).unwrap();
        let mut s = CommSchedule::new(world, table);
        // random ops with deps only on already-added ops (guarantees DAG)
        let mut added: Vec<(usize, usize)> = Vec::new();
        let mut last_writer: std::collections::HashMap<(usize, usize), (usize, usize)> =
            std::collections::HashMap::new();
        for _ in 0..rng.below(20) + 1 {
            let rank = rng.below(world);
            let mut peer = rng.below(world);
            if peer == rank {
                peer = (peer + 1) % world;
            }
            let shard = rng.below(world);
            let region =
                Region::rows(shard * (rows / world), rows / world, 8);
            let c = Chunk::new(x, region);
            let mut deps = if !added.is_empty() && rng.below(2) == 1 {
                let (dr, di) = added[rng.below(added.len())];
                vec![Dep::on(dr, di)]
            } else {
                vec![]
            };
            let kind = if rng.below(2) == 0 { TransferKind::Push } else { TransferKind::Pull };
            // order repeat writes of the same (destination, shard) after the
            // previous writer, as a race-free plan must
            let dst = if kind == TransferKind::Push { peer } else { rank };
            if let Some(&(pr, pi)) = last_writer.get(&(dst, shard)) {
                let d = Dep::on(pr, pi);
                if !deps.contains(&d) {
                    deps.push(d);
                }
            }
            let idx = s
                .add_op(rank, CommOp::P2p { kind, peer, src: c.clone(), dst: c, reduce: false, deps })
                .unwrap();
            added.push((rank, idx));
            last_writer.insert((dst, shard), (rank, idx));
        }
        validate(&s).unwrap_or_else(|e| panic!("iter {it}: {e}"));
        let order = topo_order(&s).unwrap();
        let pos: std::collections::HashMap<_, _> =
            order.iter().enumerate().map(|(i, o)| (*o, i)).collect();
        for (rank, ops) in s.per_rank.iter().enumerate() {
            for (index, op) in ops.iter().enumerate() {
                let me = pos[&syncopate::schedule::OpRef { rank, index }];
                for d in op.deps() {
                    let dep =
                        pos[&syncopate::schedule::OpRef { rank: d.rank, index: d.index }];
                    assert!(dep < me, "iter {it}: dep ordered after dependent");
                }
            }
        }
    }
}

/// Property: split_p2p preserves total link bytes and validity.
#[test]
fn prop_split_preserves_bytes_and_validity() {
    let mut rng = Rng::new(0xFACE);
    for it in 0..ITERS {
        let world = rng.below(5) + 2;
        let mut table = TensorTable::new();
        let rows = world * 8;
        let x = table.declare("x", &[rows, 16], DType::F32).unwrap();
        let s = syncopate::schedule::templates::all_gather_ring(&table, x, 0, world).unwrap();
        let n = [1usize, 2, 4, 8][rng.below(4)];
        let s2 = s.split_p2p(0, n).unwrap_or_else(|e| panic!("iter {it}: {e}"));
        validate(&s2).unwrap_or_else(|e| panic!("iter {it}: {e}"));
        assert_eq!(
            s.total_link_bytes().unwrap(),
            s2.total_link_bytes().unwrap(),
            "iter {it}"
        );
        assert_eq!(s2.num_ops(), s.num_ops() * n, "iter {it}");
    }
}

/// Property: chunk-major swizzles are always permutations, for random
/// disjoint chunk groupings.
#[test]
fn prop_swizzle_is_permutation() {
    let mut rng = Rng::new(0x5EED);
    for it in 0..ITERS {
        let g = TileGrid::gemm(
            (rng.below(8) + 1) * 32,
            (rng.below(4) + 1) * 32,
            32,
            32,
        )
        .unwrap();
        let n = g.num_tiles();
        // random disjoint groups over a random subset of tiles
        let mut tiles: Vec<usize> = (0..n).collect();
        // Fisher-Yates with our rng
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            tiles.swap(i, j);
        }
        let grouped = rng.below(n + 1);
        let ngroups = if grouped == 0 { 0 } else { rng.below(grouped) + 1 };
        let mut groups = std::collections::HashMap::new();
        if ngroups > 0 {
            for (i, &t) in tiles[..grouped].iter().enumerate() {
                groups.entry(i % ngroups).or_insert_with(Vec::new).push(t);
            }
        }
        let arrival: Vec<usize> = (0..groups.len()).collect();
        let intra = [IntraOrder::RowMajor, IntraOrder::Snake][rng.below(2)];
        let s = TileScheduler::chunk_major(&g, &groups, &arrival, intra)
            .unwrap_or_else(|e| panic!("iter {it}: {e}"));
        assert!(s.is_permutation(n), "iter {it}");
    }
}

/// Property: simulated makespan is monotone in communication volume
/// (same plan shape, larger tensors == no faster).
#[test]
fn prop_sim_monotone_in_bytes() {
    let topo = syncopate::hw::catalog::topology("h100_node", 4).unwrap();
    let mut prev = 0.0;
    for tokens in [2048usize, 4096, 8192, 16384] {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, tokens, 4);
        let (p, params) = compile_operator(&op, &TuneConfig::default(), &topo).unwrap();
        let t = simulate(&p, &topo, params).unwrap().makespan_us;
        assert!(t >= prev, "tokens {tokens}: {t} < {prev}");
        prev = t;
    }
}

/// Property (real numerics): random seeds, splits and worlds all verify
/// against the oracle — the distributed execution is value-correct for any
/// valid configuration.
#[test]
fn prop_exec_numerics_random_configs() {
    let rt = Runtime::open_default().expect("open_default falls back to host-ref; cannot fail");
    let mut rng = Rng::new(0xE0E0);
    for it in 0..8 {
        let world = [2usize, 4][rng.below(2)];
        let split = [1usize, 2, 4][rng.below(3)];
        let seed = rng.next_u64();
        let case = execases::ag_gemm(world, split, seed).unwrap();
        run_and_verify(case, &rt).unwrap_or_else(|e| panic!("iter {it} seed {seed}: {e}"));
    }
    for it in 0..4 {
        let world = [2usize, 4][rng.below(2)];
        let seed = rng.next_u64();
        let case = execases::gemm_ar(world, seed).unwrap();
        run_and_verify(case, &rt).unwrap_or_else(|e| panic!("iter {it} seed {seed}: {e}"));
    }
}

/// Property: backend feasibility — the autotuner never returns an
/// infeasible realization across random operators.
#[test]
fn prop_autotune_respects_feasibility() {
    let mut rng = Rng::new(0xFEA5);
    let topo = syncopate::hw::catalog::topology("h100_node", 4).unwrap();
    for _ in 0..6 {
        let kind = [OpKind::AgGemm, OpKind::GemmRs, OpKind::GemmAr][rng.below(3)];
        let tokens = (rng.below(3) + 1) * 4096;
        let op = OperatorInstance::gemm(kind, &LLAMA3_8B, tokens, 4);
        let r = syncopate::autotune::tune(&op, &topo, syncopate::autotune::Budget::Quick)
            .unwrap();
        let needs_reduce = matches!(kind, OpKind::GemmRs | OpKind::GemmAr);
        if needs_reduce {
            assert!(syncopate::backend::caps(r.cfg.real.backend).supports_reduce);
        }
        if r.cfg.real.backend == BackendKind::CopyEngine {
            assert_eq!(r.cfg.real.comm_sms, 0);
        }
        let _ = Realization::new(r.cfg.real.backend, r.cfg.real.comm_sms);
    }
}
