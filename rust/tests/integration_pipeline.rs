//! Integration: the paper-scale compilation + simulation pipeline across
//! the full operator/world/baseline matrix, plus the report generators.

use syncopate::autotune::{self, Budget};
use syncopate::backend::BackendKind;
use syncopate::baselines::{self, Baseline};
use syncopate::codegen::Realization;
use syncopate::coordinator::operators::{compile_operator, compile_operator_barrier_sync};
use syncopate::coordinator::TuneConfig;
use syncopate::reports;
use syncopate::sim::engine::simulate;
use syncopate::workload::{fig8_suite, fig9_suite, OpKind, OperatorInstance, LLAMA3_70B, LLAMA3_8B};

fn cfg_for(kind: OpKind) -> TuneConfig {
    match kind {
        OpKind::GemmRs | OpKind::GemmAr => TuneConfig {
            real: Realization::new(BackendKind::LdStSpecialized, 32),
            ..Default::default()
        },
        _ => TuneConfig::default(),
    }
}

#[test]
fn whole_fig8_suite_compiles_and_simulates() {
    for op in fig8_suite() {
        let topo = syncopate::hw::catalog::topology("h100_node", op.world).unwrap();
        let cfg = cfg_for(op.kind);
        let (plan, params) =
            compile_operator(&op, &cfg, &topo).unwrap_or_else(|e| panic!("{}: {e}", op.label()));
        let r = simulate(&plan, &topo, params).unwrap_or_else(|e| panic!("{}: {e}", op.label()));
        assert!(r.makespan_us > 0.0 && r.tflops() > 1.0, "{}", op.label());
    }
}

#[test]
fn whole_fig9_suite_compiles_and_simulates() {
    for op in fig9_suite() {
        let topo = syncopate::hw::catalog::topology("h100_node", op.world).unwrap();
        let cfg = TuneConfig { split: 1, ..TuneConfig::default() };
        let (plan, params) =
            compile_operator(&op, &cfg, &topo).unwrap_or_else(|e| panic!("{}: {e}", op.label()));
        let r = simulate(&plan, &topo, params).unwrap();
        assert!(r.tflops() > 1.0, "{}: {}", op.label(), r.tflops());
    }
}

#[test]
fn every_baseline_covers_every_supported_operator() {
    let ops = [
        OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 8192, 8),
        OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_8B, 8192, 8),
        OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_8B, 8192, 8),
        OperatorInstance::attention(OpKind::RingAttn, &LLAMA3_8B, 8192, 8),
        OperatorInstance::attention(OpKind::AttnHp, &LLAMA3_8B, 8192, 8),
    ];
    let topo = syncopate::hw::catalog::topology("h100_node", 8).unwrap();
    for op in ops {
        for b in Baseline::ALL {
            if !b.supports(&op) {
                continue;
            }
            let (p, params) = baselines::plan(b, &op, &topo)
                .unwrap_or_else(|e| panic!("{:?} on {}: {e}", b, op.label()));
            let r = simulate(&p, &topo, params).unwrap();
            assert!(r.makespan_us > 0.0, "{b:?} {}", op.label());
        }
    }
}

#[test]
fn tuned_beats_or_matches_every_automatic_baseline() {
    // the paper's core claim at operator level
    let topo = syncopate::hw::catalog::topology("h100_node", 8).unwrap();
    for op in [
        OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, 8192, 8),
        OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_70B, 8192, 8),
        OperatorInstance::attention(OpKind::RingAttn, &LLAMA3_8B, 16384, 8),
    ] {
        let tuned = autotune::tune(&op, &topo, Budget::Quick).unwrap();
        for b in [Baseline::TritonNccl, Baseline::KernelLevel] {
            let (p, params) = baselines::plan(b, &op, &topo).unwrap();
            let base = simulate(&p, &topo, params).unwrap().makespan_us;
            assert!(
                tuned.makespan_us <= base * 1.02,
                "{} vs {:?}: {} > {}",
                op.label(),
                b,
                tuned.makespan_us,
                base
            );
        }
    }
}

#[test]
fn minimal_sync_never_loses_to_barrier() {
    let topo = syncopate::hw::catalog::topology("h100_node", 8).unwrap();
    for op in [
        OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, 8192, 8),
        OperatorInstance::attention(OpKind::RingAttn, &LLAMA3_8B, 16384, 8),
    ] {
        let cfg = cfg_for(op.kind);
        let (p1, params) = compile_operator(&op, &cfg, &topo).unwrap();
        let (p2, _) = compile_operator_barrier_sync(&op, &cfg, &topo).unwrap();
        let a = simulate(&p1, &topo, params).unwrap();
        let b = simulate(&p2, &topo, params).unwrap();
        assert!(a.makespan_us <= b.makespan_us * 1.001, "{}", op.label());
        assert!(a.exposed_wait_us <= b.exposed_wait_us + 1e-6, "{}", op.label());
    }
}

#[test]
fn simulation_is_deterministic_across_runs() {
    let topo = syncopate::hw::catalog::topology("h100_node", 8).unwrap();
    let op = OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_70B, 8192, 8);
    let cfg = cfg_for(op.kind);
    let (plan, params) = compile_operator(&op, &cfg, &topo).unwrap();
    let a = simulate(&plan, &topo, params).unwrap();
    let b = simulate(&plan, &topo, params).unwrap();
    assert_eq!(a.makespan_us, b.makespan_us);
    assert_eq!(a.timeline.spans.len(), b.timeline.spans.len());
}

#[test]
fn multinode_topology_end_to_end() {
    let topo = syncopate::hw::catalog::topology_nodes("h100_multinode", 2, 8).unwrap();
    let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 8192, 8);
    let cfg = TuneConfig {
        real: Realization::new(BackendKind::LdStSpecialized, 32),
        ..Default::default()
    };
    let (plan, params) = compile_operator(&op, &cfg, &topo).unwrap();
    let multi = simulate(&plan, &topo, params).unwrap();
    // same operator on a single 8-GPU node is faster (no IB hops)
    let topo1 = syncopate::hw::catalog::topology("h100_node", 8).unwrap();
    let (plan1, params1) = compile_operator(&op, &cfg, &topo1).unwrap();
    let single = simulate(&plan1, &topo1, params1).unwrap();
    assert!(multi.makespan_us > single.makespan_us);
}

#[test]
fn report_generators_produce_full_tables() {
    // static figures are cheap; run them end-to-end
    assert_eq!(reports::table2().rows.len(), 3);
    assert_eq!(reports::fig2a().rows.len(), 6);
    assert!(reports::fig2b().unwrap().rows.len() >= 4);
    assert_eq!(reports::fig2c().rows.len(), 6);
    assert_eq!(reports::fig2d().rows.len(), 7);
    let f11a = reports::fig11a().unwrap();
    assert_eq!(f11a.rows.len(), 2);
    let f11b = reports::fig11b().unwrap();
    assert_eq!(f11b.rows.len(), 6);
}

#[test]
fn fig10_integration_improves_on_native() {
    let t = reports::fig10(Budget::Quick).unwrap();
    assert_eq!(t.rows.len(), 3);
    for (label, row) in &t.rows {
        let native = row[0];
        let ours = row[1];
        assert!(ours < native, "{label}: +syncopate {ours} vs native {native}");
        // all three comm lowering paths produce finite latencies
        assert!(row[2..].iter().all(|v| v.is_finite() && *v > 0.0), "{label}");
    }
}

#[test]
fn split_sweep_has_interior_optimum_for_ar() {
    let topo = syncopate::hw::catalog::topology("h100_node", 8).unwrap();
    let op = OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_70B, 8192, 8);
    let mut times = Vec::new();
    for split in [1usize, 2, 4, 8, 16] {
        let cfg = TuneConfig {
            split,
            real: Realization::new(BackendKind::LdStSpecialized, 32),
            ..Default::default()
        };
        let (p, params) = compile_operator(&op, &cfg, &topo).unwrap();
        times.push(simulate(&p, &topo, params).unwrap().makespan_us);
    }
    let best = times.iter().copied().fold(f64::INFINITY, f64::min);
    assert!(times[0] > best, "split=1 should not be optimal: {times:?}");
    assert!(*times.last().unwrap() > best, "max split should not be optimal: {times:?}");
}
