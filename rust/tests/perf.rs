//! Integration: critical-path profiler + perf-regression harness
//! (DESIGN.md §19).
//!
//! Three claims:
//!
//! 1. **Engine stability** — the critical-path extraction is structural:
//!    for every registry exec case at worlds 2/4/8, the sequential and
//!    parallel engines' traces yield the SAME timestamp-free critical op
//!    sequence (the DAG and the model weights depend only on the prepared
//!    plan, never on measured timestamps).
//! 2. **Blame completeness** — the blame decomposition
//!    (compute + comm + wait + sched) sums to the traced wall makespan
//!    within 1e-6 relative, for every case/world/engine.
//! 3. **The gate flags real regressions and nothing else** — an injected
//!    2x slowdown of a measured baseline is flagged as significant, while
//!    two identical back-to-back recordings of the same case report no
//!    regression.

use syncopate::coordinator::execases::{self, CaseParams};
use syncopate::exec::{ExecMode, ExecOptions};
use syncopate::perf::{self, Baseline, PerfCase};
use syncopate::runtime::Runtime;
use syncopate::trace;

fn rt() -> Runtime {
    Runtime::open_default().expect("open_default falls back to host-ref; cannot fail")
}

fn opts(mode: ExecMode) -> ExecOptions {
    ExecOptions {
        mode,
        wait_timeout: std::time::Duration::from_secs(30),
        ..ExecOptions::parallel()
    }
}

#[test]
fn critical_path_is_engine_stable_and_blame_sums_to_makespan() {
    let rt = rt();
    for world in [2usize, 4, 8] {
        for spec in execases::CASES {
            let params = CaseParams { world, ..Default::default() };
            let mut key_seqs = Vec::new();
            for mode in [ExecMode::Sequential, ExecMode::Parallel] {
                let case = spec
                    .build(&params)
                    .unwrap_or_else(|e| panic!("{} w{world}: {e}", spec.name));
                let (_, trace) = execases::run_and_verify_traced(case, &rt, &opts(mode))
                    .unwrap_or_else(|e| panic!("{} w{world} {mode:?}: {e}", spec.name));
                let ctx = format!("{} w{world} {mode:?}", spec.name);
                let cp = perf::critical_path(&trace)
                    .unwrap_or_else(|e| panic!("{ctx}: critical_path: {e}"));
                assert!(!cp.nodes.is_empty(), "{ctx}: empty critical path");
                assert!(cp.wall_makespan_us > 0.0, "{ctx}: nothing measured");
                // blame is a complete partition of the wall makespan
                let total = cp.blame.total_us();
                assert!(
                    (total - cp.wall_makespan_us).abs()
                        <= 1e-6 * cp.wall_makespan_us.max(1.0),
                    "{ctx}: blame {total} != wall {}",
                    cp.wall_makespan_us
                );
                // the path is a real chain: node spans only move forward
                // in per-rank program order along equal ranks
                for w in cp.nodes.windows(2) {
                    if w[0].rank == w[1].rank {
                        assert!(
                            w[0].op <= w[1].op,
                            "{ctx}: path goes backwards: {:?} -> {:?}",
                            (w[0].rank, w[0].op),
                            (w[1].rank, w[1].op)
                        );
                    }
                }
                key_seqs.push(cp.keys());
            }
            assert_eq!(
                key_seqs[0], key_seqs[1],
                "{} w{world}: engines must agree on the critical op sequence",
                spec.name
            );
        }
    }
}

#[test]
fn critical_overlay_passes_the_chrome_schema_check() {
    let rt = rt();
    let case = execases::build_case("ag-gemm", &CaseParams { world: 2, ..Default::default() })
        .unwrap();
    let (_, trace) =
        execases::run_and_verify_traced(case, &rt, &opts(ExecMode::Sequential)).unwrap();
    let cp = perf::critical_path(&trace).unwrap();
    let text = trace::to_chrome_json_overlay(&trace, &cp.keys());
    // overlay is still a schema-valid export of every span...
    assert_eq!(trace::check_chrome_schema(&text).unwrap(), trace.events.len());
    // ...with the critical spans marked for the viewer
    assert!(text.contains("\"critical\": true"), "no span marked critical");
}

#[test]
fn what_if_bounds_are_sane_on_a_measured_trace() {
    let rt = rt();
    let case = execases::build_case("ag-gemm", &CaseParams { world: 2, ..Default::default() })
        .unwrap();
    let (_, trace) =
        execases::run_and_verify_traced(case, &rt, &opts(ExecMode::Sequential)).unwrap();
    let cp = perf::critical_path(&trace).unwrap();
    // perfect comm (scale 0) can save at most the comm blame; the bound
    // never goes below wall - comm and speedup is >= 1
    let w = cp.what_if_scale(0.0);
    assert!(w.saved_us <= cp.blame.comm_us + 1e-9, "{w:?}");
    assert!(w.bound_us + w.saved_us >= cp.wall_makespan_us - 1e-9, "{w:?}");
    assert!(w.speedup_bound >= 1.0, "{w:?}");
    // no change -> no saving
    let same = cp.what_if_scale(1.0);
    assert_eq!(same.saved_us, 0.0, "{same:?}");
    assert_eq!(same.bound_us, cp.wall_makespan_us, "{same:?}");
}

/// Measure one registry case the way `perf record` does: N hot-path
/// iterations on the arena-reusing entry point, summarized as median+MAD.
fn measure(case_name: &str, repeat: usize, rt: &Runtime) -> PerfCase {
    let params = CaseParams { world: 2, ..Default::default() };
    let case = execases::build_case(case_name, &params).unwrap();
    let fingerprint = syncopate::hw::fingerprint(&case.topo);
    let prep = syncopate::exec::prepare(&case.plan, &case.sched.tensors).unwrap();
    let mut arena = syncopate::exec::PlanArena::new(&prep);
    let opts = opts(ExecMode::Parallel);
    let mut durs = Vec::with_capacity(repeat);
    for i in 0..=repeat {
        let store = case.store.clone();
        let t0 = std::time::Instant::now();
        syncopate::exec::run_prepared_reusing(&prep, &mut arena, &store, rt, &opts).unwrap();
        if i > 0 {
            durs.push(t0.elapsed().as_secs_f64() * 1e6);
        }
    }
    let (median_us, mad_us) = perf::median_mad(&durs);
    PerfCase {
        case: case_name.into(),
        world: 2,
        engine: "parallel".into(),
        fingerprint,
        samples: durs.len(),
        median_us,
        mad_us,
    }
}

#[test]
fn gate_flags_injected_slowdown_but_not_back_to_back_reruns() {
    let rt = rt();
    let mut base = Baseline::default();
    base.insert(measure("ag-gemm", 9, &rt));

    // a genuinely identical re-recording never regresses (same medians)
    let rows = perf::diff(&base, &base.clone(), 5.0);
    assert_eq!(perf::regressions(&rows), 0, "{rows:?}");

    // two real back-to-back recordings: no significant regression at the
    // advisory threshold (the noise band absorbs scheduler jitter)
    let mut rerun = Baseline::default();
    rerun.insert(measure("ag-gemm", 9, &rt));
    let rows = perf::diff(&base, &rerun, 50.0);
    assert_eq!(
        perf::regressions(&rows),
        0,
        "back-to-back identical runs must not gate: {rows:?}"
    );

    // an injected 2x slowdown of the same measurement IS flagged
    let mut slowed = base.clone();
    for c in &mut slowed.cases {
        c.median_us *= 2.0;
    }
    let rows = perf::diff(&base, &slowed, 5.0);
    assert_eq!(perf::regressions(&rows), 1, "{rows:?}");
    assert!((rows[0].delta_pct - 100.0).abs() < 1e-9, "{rows:?}");

    // and the baseline file format round-trips the measured cells
    let back = Baseline::from_json(&base.to_json()).unwrap();
    assert_eq!(back, base);
}
