//! Integration: chunk-level tracing + measured-curve calibration — the
//! sim↔execution loop (DESIGN.md §14).
//!
//! Three claims:
//!
//! 1. **Completeness** — a traced run of every registry exec case, at
//!    worlds 2/4/8, under BOTH engines, captures exactly the plan's
//!    events: one transfer span per issued transfer, one wait span per
//!    `Wait` op, one kernel span per compute call, one segment span per
//!    call-carrying `Compute` op — and the two engines produce identical
//!    timestamp-free event sets.
//! 2. **Round trip** — the Chrome `trace_event` export passes the schema
//!    check and parses back into the identical trace.
//! 3. **Calibration closes the loop** — `calibrate(trace(exec run))`
//!    emits a `.topo` that lints clean, carries a fitted curve row for
//!    every backend the trace observed, and STRICTLY lowers the
//!    sim-vs-trace makespan divergence vs. the uncalibrated catalog
//!    entry (asserted over 3+ registry cases).

use syncopate::codegen::PlanOp;
use syncopate::coordinator::execases::{self, CaseParams};
use syncopate::exec::{ExecMode, ExecOptions};
use syncopate::hw;
use syncopate::runtime::Runtime;
use syncopate::sim::engine::simulate;
use syncopate::sim::SimParams;
use syncopate::trace::{self, TraceKind};

fn rt() -> Runtime {
    Runtime::open_default().expect("open_default falls back to host-ref; cannot fail")
}

fn opts(mode: ExecMode) -> ExecOptions {
    ExecOptions {
        mode,
        wait_timeout: std::time::Duration::from_secs(30),
        ..ExecOptions::parallel()
    }
}

/// Expected per-kind event counts straight from the compiled plan.
fn expected_counts(plan: &syncopate::codegen::ExecutablePlan) -> (usize, usize, usize, usize) {
    let mut waits = 0;
    let mut kernels = 0;
    let mut segs = 0;
    for prog in &plan.per_rank {
        for op in &prog.ops {
            match op {
                PlanOp::Wait(_) => waits += 1,
                PlanOp::Compute(seg) => {
                    kernels += seg.calls.len();
                    if !seg.calls.is_empty() {
                        segs += 1;
                    }
                }
                _ => {}
            }
        }
    }
    (plan.total_transfers(), waits, kernels, segs)
}

#[test]
fn traced_event_counts_match_plan_for_every_registry_case_both_engines() {
    let rt = rt();
    for world in [2usize, 4, 8] {
        for spec in execases::CASES {
            let params = CaseParams { world, ..Default::default() };
            let mut keysets = Vec::new();
            for mode in [ExecMode::Sequential, ExecMode::Parallel] {
                let case = spec.build(&params)
                    .unwrap_or_else(|e| panic!("{} w{world}: {e}", spec.name));
                let (want_x, want_w, want_k, want_s) = expected_counts(&case.plan);
                let (stats, trace) = execases::run_and_verify_traced(case, &rt, &opts(mode))
                    .unwrap_or_else(|e| panic!("{} w{world} {mode:?}: {e}", spec.name));
                let ctx = format!("{} w{world} {mode:?}", spec.name);
                assert_eq!(trace.count("transfer"), want_x, "{ctx}: transfer events");
                assert_eq!(trace.count("wait"), want_w, "{ctx}: wait events");
                assert_eq!(trace.count("kernel"), want_k, "{ctx}: kernel events");
                assert_eq!(trace.count("compute"), want_s, "{ctx}: segment events");
                // trace agrees with the engine's own accounting
                assert_eq!(trace.count("transfer"), stats.transfers, "{ctx}");
                assert_eq!(trace.count("wait"), stats.waits_hit, "{ctx}");
                assert_eq!(trace.count("kernel"), stats.compute_calls, "{ctx}");
                assert_eq!(trace.world, world, "{ctx}");
                assert!(!trace.fingerprint.is_empty(), "{ctx}: fingerprint stamped");
                for ev in &trace.events {
                    assert!(
                        ev.end_us >= ev.start_us && ev.start_us >= 0.0,
                        "{ctx}: negative span {ev:?}"
                    );
                }
                keysets.push(trace.event_keys());
            }
            assert_eq!(
                keysets[0], keysets[1],
                "{} w{world}: engines must produce identical event sets",
                spec.name
            );
        }
    }
}

#[test]
fn chrome_export_round_trips_through_the_schema_check() {
    let rt = rt();
    let case = execases::build_case("ag-gemm", &CaseParams { world: 2, ..Default::default() })
        .unwrap();
    let (_, mut trace) =
        execases::run_and_verify_traced(case, &rt, &opts(ExecMode::Sequential)).unwrap();
    trace.set_meta("registry-case", "ag-gemm");
    let text = trace::to_chrome_json(&trace);
    // schema check counts exactly the captured spans
    assert_eq!(trace::check_chrome_schema(&text).unwrap(), trace.events.len());
    // and the parse inverts the print exactly (events are already in lane
    // order, so the whole struct round-trips)
    let back = trace::from_chrome_json(&text).unwrap();
    assert_eq!(back, trace);
    // a trace with the header stripped is rejected, not misread
    let beheaded = text.replace("\"syncopate\"", "\"somebody-else\"");
    assert!(trace::check_chrome_schema(&beheaded).is_err());
}

#[test]
fn calibration_lowers_sim_vs_trace_divergence_and_lints_clean() {
    // The ISSUE 5 acceptance round trip, over three registry cases: a
    // host-reference `exec --trace`-equivalent run on the default catalog
    // topology produces a trace from which `calibrate` emits a `.topo`
    // that (a) parses/lints clean, (b) carries a fitted curve row for
    // every backend observed, and (c) STRICTLY lowers sim-vs-trace
    // makespan divergence vs. the uncalibrated catalog entry.
    //
    // The host-reference runtime is orders of magnitude off the H100
    // curves the catalog describes (CPU gemms, memcpy transfers), so the
    // uncalibrated divergence is enormous; any honest fit must land
    // closer. The sequential engine keeps the capture deterministic;
    // divergence is measured against the busy makespan, which is
    // scheduling-noise-free (see trace::analyze).
    let rt = rt();
    let desc = hw::catalog::desc(hw::catalog::DEFAULT).unwrap();
    for case_name in ["ag-gemm", "gemm-rs", "a2a-gemm"] {
        let params = CaseParams { world: 2, ..Default::default() };
        let case = execases::build_case(case_name, &params).unwrap();
        let plan = case.plan.clone();
        let topo = case.topo.clone();
        let (_, trace) =
            execases::run_and_verify_traced(case, &rt, &opts(ExecMode::Sequential)).unwrap();
        let report = trace::analyze(&trace);
        assert!(report.busy_makespan_us > 0.0, "{case_name}: nothing measured");

        let sim_before = simulate(&plan, &topo, SimParams::default()).unwrap().makespan_us;
        let div_before = report.divergence(sim_before);

        let cal = trace::calibrate(&trace, &desc)
            .unwrap_or_else(|e| panic!("{case_name}: calibrate: {e}"));

        // (b) every backend observed in the trace has a fitted row
        let mut observed: Vec<_> = trace
            .events
            .iter()
            .filter_map(|e| match &e.kind {
                TraceKind::Transfer { backend, .. } => Some(*backend),
                _ => None,
            })
            .collect();
        observed.sort_by_key(|b| b.index());
        observed.dedup();
        assert!(!observed.is_empty(), "{case_name}: no transfers traced");
        for b in &observed {
            assert!(
                cal.curves.iter().any(|f| f.backend == *b),
                "{case_name}: backend {} observed but not fitted",
                b.name()
            );
        }

        // (a) the emitted text lints clean: parse(print) == desc, and it
        // instantiates at the traced world
        let text = hw::print_desc(&cal.desc);
        let reparsed = hw::parse_desc(&text)
            .unwrap_or_else(|e| panic!("{case_name}: calibrated .topo does not parse: {e}"));
        assert_eq!(reparsed, cal.desc, "{case_name}: print->parse round trip");
        let cal_topo = cal.desc.instantiate(2).unwrap();

        // (c) strictly lower divergence than the uncalibrated entry
        let sim_after = simulate(&plan, &cal_topo, SimParams::default()).unwrap().makespan_us;
        let div_after = report.divergence(sim_after);
        assert!(
            div_after < div_before,
            "{case_name}: divergence must strictly drop: before {div_before:.4} \
             (sim {sim_before:.1}us), after {div_after:.4} (sim {sim_after:.1}us), \
             measured busy {:.1}us",
            report.busy_makespan_us
        );
    }
}

#[test]
fn calibration_refuses_cross_shape_traces() {
    // a trace captured on h100_node must not calibrate a100_node — the
    // fingerprint key is the guard
    let rt = rt();
    let case = execases::build_case("ag-gemm", &CaseParams { world: 2, ..Default::default() })
        .unwrap();
    let (_, trace) =
        execases::run_and_verify_traced(case, &rt, &opts(ExecMode::Sequential)).unwrap();
    let a100 = hw::catalog::desc("a100_node").unwrap();
    let e = trace::calibrate(&trace, &a100).unwrap_err();
    assert!(e.to_string().contains("must not cross machine shapes"), "{e}");
    // and the matching shape is accepted
    let h100 = hw::catalog::desc("h100_node").unwrap();
    assert!(trace::calibrate(&trace, &h100).is_ok());
}

#[test]
fn traced_run_leaves_results_and_stats_unchanged() {
    // tracing must be observation-only: same verified numerics (checked
    // inside run_and_verify_traced) and same stats as the untraced path
    let rt = rt();
    let params = CaseParams { world: 4, split: 2, ..Default::default() };
    let untraced = execases::build_case("ag-gemm", &params).unwrap();
    let plain = execases::run_and_verify_with(untraced, &rt, &opts(ExecMode::Parallel)).unwrap();
    let traced_case = execases::build_case("ag-gemm", &params).unwrap();
    let (stats, _) =
        execases::run_and_verify_traced(traced_case, &rt, &opts(ExecMode::Parallel)).unwrap();
    assert_eq!(plain, stats);
}
