//! Integration: the manifest-backed runtime against the real AOT artifacts.
//!
//! Requires `make artifacts`: each test reads the manifest produced by the
//! L1/L2 Python layer and checks kernel numerics against host oracles —
//! the cross-language contract test of the three-layer stack (executed
//! through PJRT when built with `--features xla`, and through the
//! host-reference backend validated against the same manifest otherwise).
//!
//! On a bare checkout (no `artifacts/manifest.tsv`) every test here SKIPS
//! with a message rather than failing — the execution stack itself is
//! covered artifact-free by integration_exec.rs / integration_parallel.rs
//! via the host-reference runtime.

use syncopate::exec::verify::{assert_allclose, host_attention, host_gelu, host_gemm};
use syncopate::runtime::Runtime;
use syncopate::util::Rng;

/// The manifest-backed runtime, or `None` (with a clear skip message) when
/// `make artifacts` has not been run.
fn rt() -> Option<Runtime> {
    if !Runtime::artifacts_available() {
        eprintln!(
            "SKIP: {} not found — run `make artifacts` to exercise the AOT artifact contract",
            Runtime::artifacts_dir().join("manifest.tsv").display()
        );
        return None;
    }
    Some(Runtime::open_default().expect("artifacts present but runtime failed to open"))
}

#[test]
fn manifest_lists_all_kernel_families() {
    let Some(rt) = rt() else { return };
    let names = rt.names();
    assert!(names.iter().any(|n| n.starts_with("gemm_")));
    assert!(names.iter().any(|n| n.starts_with("attn_step_")));
    assert!(names.iter().any(|n| n.starts_with("attn_finalize_")));
    assert!(names.iter().any(|n| n.starts_with("ffn_shard_")));
    assert!(names.iter().any(|n| n.starts_with("add_")));
    assert!(names.len() >= 13, "{names:?}");
}

#[test]
fn gemm_artifacts_match_host_oracle() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(11);
    for tm in [8usize, 16, 32, 64, 128] {
        let name = format!("gemm_{tm}x128x128");
        let a = rng.vec_f32(tm * 128);
        let b = rng.vec_f32(128 * 128);
        let outs = rt.execute(&name, &[(&a, &[tm, 128]), (&b, &[128, 128])]).unwrap();
        let want = host_gemm(&a, &b, tm, 128, 128);
        assert_allclose(&outs[0], &want, 1e-4, 1e-4, &name).unwrap();
    }
}

#[test]
fn attn_step_chain_matches_full_attention() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(21);
    let (sq, d, world) = (64usize, 64usize, 4usize);
    let q = rng.vec_f32(sq * d);
    let k: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(sq * d)).collect();
    let v: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(sq * d)).collect();

    let mut acc = vec![0.0f32; sq * d];
    let mut m = vec![-1e30f32; sq];
    let mut l = vec![0.0f32; sq];
    for step in 0..world {
        let outs = rt
            .execute(
                "attn_step_q64d64k64",
                &[
                    (&q, &[sq, d]),
                    (&k[step], &[sq, d]),
                    (&v[step], &[sq, d]),
                    (&acc, &[sq, d]),
                    (&m, &[sq]),
                    (&l, &[sq]),
                ],
            )
            .unwrap();
        acc = outs[0].clone();
        m = outs[1].clone();
        l = outs[2].clone();
    }
    let outs = rt
        .execute("attn_finalize_q64d64", &[(&acc, &[sq, d]), (&l, &[sq])])
        .unwrap();
    let k_full: Vec<f32> = k.concat();
    let v_full: Vec<f32> = v.concat();
    let want = host_attention(&q, &k_full, &v_full, sq, world * sq, d, 1.0 / (d as f32).sqrt());
    assert_allclose(&outs[0], &want, 5e-4, 5e-4, "ring chain").unwrap();
}

#[test]
fn attn_step_split_chunk_artifacts() {
    // the k16/k32 variants fold smaller chunks but compose identically
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(31);
    let (sq, d) = (64usize, 64usize);
    let q = rng.vec_f32(sq * d);
    let k = rng.vec_f32(sq * d);
    let v = rng.vec_f32(sq * d);

    let run = |chunk: usize| {
        let name = format!("attn_step_q64d64k{chunk}");
        let mut acc = vec![0.0f32; sq * d];
        let mut m = vec![-1e30f32; sq];
        let mut l = vec![0.0f32; sq];
        for c in 0..(sq / chunk) {
            let ks = &k[c * chunk * d..(c + 1) * chunk * d];
            let vs = &v[c * chunk * d..(c + 1) * chunk * d];
            let outs = rt
                .execute(
                    &name,
                    &[
                        (&q, &[sq, d]),
                        (ks, &[chunk, d]),
                        (vs, &[chunk, d]),
                        (&acc, &[sq, d]),
                        (&m, &[sq]),
                        (&l, &[sq]),
                    ],
                )
                .unwrap();
            acc = outs[0].clone();
            m = outs[1].clone();
            l = outs[2].clone();
        }
        let o = rt
            .execute("attn_finalize_q64d64", &[(&acc, &[sq, d]), (&l, &[sq])])
            .unwrap();
        o[0].clone()
    };
    let o64 = run(64);
    let o32 = run(32);
    let o16 = run(16);
    assert_allclose(&o32, &o64, 1e-4, 1e-4, "k32 vs k64").unwrap();
    assert_allclose(&o16, &o64, 1e-4, 1e-4, "k16 vs k64").unwrap();
}

#[test]
fn ffn_shard_matches_host_oracle() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(41);
    let (m, d, f) = (64usize, 128usize, 64usize);
    let x = rng.vec_f32(m * d);
    let w1 = rng.vec_f32(d * f);
    let b1 = rng.vec_f32(f);
    let w2 = rng.vec_f32(f * d);
    let outs = rt
        .execute(
            "ffn_shard_64x128x64",
            &[(&x, &[m, d]), (&w1, &[d, f]), (&b1, &[f]), (&w2, &[f, d])],
        )
        .unwrap();
    let mut h = host_gemm(&x, &w1, m, d, f);
    for (i, hv) in h.iter_mut().enumerate() {
        *hv += b1[i % f];
    }
    host_gelu(&mut h);
    let want = host_gemm(&h, &w2, m, f, d);
    assert_allclose(&outs[0], &want, 5e-4, 5e-4, "ffn").unwrap();
}

#[test]
fn add_artifact() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(51);
    let x = rng.vec_f32(64 * 64);
    let y = rng.vec_f32(64 * 64);
    let outs = rt.execute("add_64x64", &[(&x, &[64, 64]), (&y, &[64, 64])]).unwrap();
    let want: Vec<f32> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
    assert_allclose(&outs[0], &want, 1e-6, 1e-6, "add").unwrap();
}

#[test]
fn shape_and_arity_validation() {
    let Some(rt) = rt() else { return };
    let a = vec![0.0f32; 8 * 128];
    let b = vec![0.0f32; 128 * 128];
    // wrong arity
    assert!(rt.execute("gemm_8x128x128", &[(&a, &[8, 128])]).is_err());
    // wrong shape
    assert!(rt
        .execute("gemm_8x128x128", &[(&a, &[128, 8]), (&b, &[128, 128])])
        .is_err());
    // wrong data length
    assert!(rt
        .execute("gemm_8x128x128", &[(&a[..10], &[8, 128]), (&b, &[128, 128])])
        .is_err());
    // unknown artifact
    assert!(rt.execute("nope", &[]).is_err());
}

#[test]
fn executable_cache_counts_calls() {
    let Some(rt) = rt() else { return };
    let x = vec![1.0f32; 64 * 64];
    assert_eq!(rt.num_calls(), 0);
    rt.execute("add_64x64", &[(&x, &[64, 64]), (&x, &[64, 64])]).unwrap();
    rt.execute("add_64x64", &[(&x, &[64, 64]), (&x, &[64, 64])]).unwrap();
    assert_eq!(rt.num_calls(), 2);
}
