//! Static-analysis integration (ISSUE 8 acceptance):
//!
//! * the negative corpus `examples/plans/bad/*.sched` triggers **exactly**
//!   the rule each file is annotated with (`# expect: SY-...`), and
//!   `validate` agrees with the analyzer on which of them are
//!   error-severity;
//! * the shipped good corpus analyzes clean at warn severity;
//! * every registry exec case at worlds 2/4/8 reports **zero**
//!   error-severity findings, and — being statically acyclic — never trips
//!   the parallel engine's bounded-wait deadlock verdict;
//! * `analysis::reduce` (the `plan analyze --fix` engine) is a fixpoint,
//!   keeps plans valid, and the reduced plan produces f32 state
//!   bit-identical to the original under BOTH exec engines.

use std::path::PathBuf;

use syncopate::analysis::{self, Severity};
use syncopate::backend::BackendKind;
use syncopate::codegen::{compile_comm_only, Realization};
use syncopate::coordinator::execases::{self, CaseParams};
use syncopate::exec::{run_with, ExecOptions};
use syncopate::plan_io::parse_schedule;
use syncopate::runtime::Runtime;
use syncopate::schedule::validate::validate;

fn plans_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/plans")
}

#[test]
fn bad_corpus_triggers_exactly_its_annotated_rule() {
    let dir = plans_dir().join("bad");
    let mut seen = 0usize;
    for entry in std::fs::read_dir(&dir).expect("examples/plans/bad must exist") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("sched") {
            continue;
        }
        seen += 1;
        let tag = path.display().to_string();
        let text = std::fs::read_to_string(&path).unwrap();
        let expect = text
            .lines()
            .find_map(|l| l.trim().strip_prefix("# expect:"))
            .unwrap_or_else(|| panic!("{tag}: missing `# expect: SY-...` annotation"))
            .trim()
            .to_string();
        let sched = parse_schedule(&text).unwrap_or_else(|e| panic!("{tag}: {e}"));
        let rep = analysis::run(&sched).unwrap_or_else(|e| panic!("{tag}: {e}"));
        assert!(
            rep.findings.iter().any(|f| f.rule == expect),
            "{tag}: expected {expect}, got {:?}",
            rep.findings
        );
        for f in &rep.findings {
            assert_eq!(
                f.rule, expect,
                "{tag}: unexpected extra finding {} ({})",
                f.rule, f.message
            );
        }
        // the corpus' error-severity entries are exactly the plans that
        // `validate` refuses to pass to execution
        let is_error = expect.starts_with("SY-E");
        assert_eq!(rep.has_errors(), is_error, "{tag}: severity drifted from the annotation");
        assert_eq!(
            validate(&sched).is_err(),
            is_error,
            "{tag}: validate and the analyzer must agree on error-severity plans"
        );
    }
    assert_eq!(seen, 5, "bad corpus went missing ({seen} files)");
}

#[test]
fn shipped_good_corpus_analyzes_clean() {
    // read_dir is non-recursive on purpose: bad/ lives one level down
    let mut seen = 0usize;
    for entry in std::fs::read_dir(plans_dir()).expect("examples/plans must exist") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("sched") {
            continue;
        }
        seen += 1;
        let tag = path.display().to_string();
        let sched = parse_schedule(&std::fs::read_to_string(&path).unwrap())
            .unwrap_or_else(|e| panic!("{tag}: {e}"));
        let rep = analysis::run(&sched).unwrap_or_else(|e| panic!("{tag}: {e}"));
        let noisy: Vec<_> =
            rep.findings.iter().filter(|f| f.severity != Severity::Info).collect();
        assert!(noisy.is_empty(), "{tag}: shipped plan must analyze clean, got {noisy:?}");
    }
    assert!(seen >= 3, "good corpus went missing ({seen} files)");
}

#[test]
fn registry_cases_analyze_without_errors_and_never_deadlock() {
    let rt = Runtime::open_default().unwrap();
    let mut swept = 0usize;
    for spec in execases::CASES {
        for world in [2usize, 4, 8] {
            let params = CaseParams { world, ..Default::default() };
            // some cases reject some shapes: a named build error is a skip
            let Ok(case) = spec.build(&params) else { continue };
            let tag = format!("{} w{world}", spec.name);
            let rep = analysis::run(&case.sched).unwrap_or_else(|e| panic!("{tag}: {e}"));
            assert_eq!(
                rep.count(Severity::Error),
                0,
                "{tag}: error findings on a registry case: {:?}",
                rep.findings
            );
            // statically acyclic (no SY-E003) -> the runtime bounded-wait
            // verdict must never fire for this plan
            execases::run_and_verify_with(case, &rt, &ExecOptions::parallel())
                .unwrap_or_else(|e| panic!("{tag}: parallel engine tripped: {e}"));
            swept += 1;
        }
    }
    assert!(swept >= 20, "registry sweep degenerated: only {swept} case-worlds ran");
}

#[test]
fn fix_reduced_registry_plans_run_bit_identically_in_both_engines() {
    let rt = Runtime::open_default().unwrap();
    let real = || Realization::new(BackendKind::LdStSpecialized, 16);
    let mut reduced_any = 0usize;
    for spec in execases::CASES {
        for world in [2usize, 4, 8] {
            let params = CaseParams { world, ..Default::default() };
            let Ok(probe) = spec.build(&params) else { continue };
            let tag = format!("{} w{world}", spec.name);
            let (reduced, removed) =
                analysis::reduce(&probe.sched).unwrap_or_else(|e| panic!("{tag}: {e}"));
            validate(&reduced).unwrap_or_else(|e| panic!("{tag}: reduced plan invalid: {e}"));
            assert_eq!(reduced.num_ops(), probe.sched.num_ops(), "{tag}: reduce dropped ops");
            // the reduction is a fixpoint: a second pass finds nothing
            assert!(
                analysis::reduce(&reduced).unwrap().1.is_empty(),
                "{tag}: reduce is not a fixpoint"
            );
            if !removed.is_empty() {
                reduced_any += 1;
            }
            let topo = &probe.topo;
            let plan_orig = compile_comm_only(&probe.sched, real(), topo)
                .unwrap_or_else(|e| panic!("{tag}: {e}"));
            let plan_red =
                compile_comm_only(&reduced, real(), topo).unwrap_or_else(|e| panic!("{tag}: {e}"));
            // four identically-seeded stores: {orig, reduced} x {seq, par}.
            // build() is deterministic per seed, so each rebuild reseeds the
            // same initial state.
            let mut states: Vec<Vec<Vec<f32>>> = Vec::new();
            for (plan, opts) in [
                (&plan_orig, ExecOptions::sequential()),
                (&plan_orig, ExecOptions::parallel()),
                (&plan_red, ExecOptions::sequential()),
                (&plan_red, ExecOptions::parallel()),
            ] {
                let case = spec.build(&params).unwrap();
                run_with(plan, &case.sched.tensors, &case.store, &rt, opts)
                    .unwrap_or_else(|e| panic!("{tag}: {e}"));
                let mut state = Vec::new();
                for r in 0..world {
                    for name in case.store.names() {
                        state.push(case.store.get(r, name).unwrap());
                    }
                }
                states.push(state);
            }
            for (i, s) in states.iter().enumerate().skip(1) {
                assert_eq!(
                    &states[0], s,
                    "{tag}: plan/engine combo {i} diverged bitwise from original+sequential"
                );
            }
        }
    }
    assert!(reduced_any >= 1, "sweep never exercised an actual reduction");
}
