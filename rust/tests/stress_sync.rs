//! Stress: the atomic synchronization core under real thread races.
//!
//! The unit tests in `exec::signals` pin the protocol pieces one at a
//! time; these tests hammer the whole board — many producers, many
//! waiters, targeted wakeups, abort storms — and then race the full
//! parallel engine over all-pairs exchange plans at worlds 4 and 8,
//! repeatedly, so a lost-wakeup or ordering bug that only shows under
//! contention has many chances to fire.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use syncopate::chunk::{DType, Region, TensorTable};
use syncopate::codegen::{ExecutablePlan, PlanOp, RankProgram};
use syncopate::exec::{run_with, BufferStore, ExecMode, ExecOptions, SignalBoard};
use syncopate::runtime::Runtime;
use syncopate::testutil::transfer_desc;

const LONG: Duration = Duration::from_secs(30);

// ---------------------------------------------------------------------------
// board-level races
// ---------------------------------------------------------------------------

#[test]
fn many_producers_many_waiters_all_released() {
    // producers set disjoint signal ranges while waiters block on subsets
    // spanning ALL producers: every waiter must be released, none may
    // verdict a deadlock while the board is live.
    for (producers, waiters) in [(4usize, 4usize), (8, 8)] {
        let per = 16usize;
        let n = producers * per;
        let board = Arc::new(SignalBoard::new(n));
        let released = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for w in 0..waiters {
                let board = Arc::clone(&board);
                let released = Arc::clone(&released);
                s.spawn(move || {
                    // one signal from each producer's range, offset by w
                    let ids: Vec<usize> =
                        (0..producers).map(|p| p * per + (w % per)).collect();
                    board.wait_all(&ids, LONG, || format!("waiter {w}")).unwrap();
                    released.fetch_add(1, Ordering::Relaxed);
                });
            }
            for p in 0..producers {
                let board = Arc::clone(&board);
                s.spawn(move || {
                    for i in 0..per {
                        board.set(p * per + i);
                        if i % 5 == 0 {
                            std::thread::yield_now();
                        }
                    }
                });
            }
        });
        assert_eq!(released.load(Ordering::Relaxed), waiters);
        for id in 0..n {
            assert!(board.is_set(id));
        }
    }
}

#[test]
fn waiters_racing_last_signal_never_miss_the_wakeup() {
    // the classic lost-wakeup window: the producer sets the signal between
    // the waiter's check and its park. 200 rounds of a one-signal rendezvous
    // with a fresh pair of threads each time.
    for round in 0..200usize {
        let board = Arc::new(SignalBoard::new(1));
        std::thread::scope(|s| {
            let b = Arc::clone(&board);
            let waiter = s.spawn(move || {
                b.wait_all(&[0], Duration::from_secs(10), || format!("round {round}"))
            });
            let b = Arc::clone(&board);
            s.spawn(move || b.set(0));
            waiter.join().unwrap().unwrap();
        });
    }
}

#[test]
fn abort_releases_every_blocked_waiter() {
    for waiters in [4usize, 8] {
        let board = Arc::new(SignalBoard::new(4));
        let errs = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for w in 0..waiters {
                let board = Arc::clone(&board);
                let errs = Arc::clone(&errs);
                s.spawn(move || {
                    let e = board
                        .wait_all(&[w % 4], LONG, || format!("w{w}"))
                        .unwrap_err();
                    assert!(e.to_string().contains("aborted"), "{e}");
                    errs.fetch_add(1, Ordering::Relaxed);
                });
            }
            // give waiters a moment to actually park, then pull the plug
            std::thread::sleep(Duration::from_millis(20));
            board.abort();
        });
        assert_eq!(errs.load(Ordering::Relaxed), waiters);
    }
}

#[test]
fn busy_producers_defer_verdicts_under_contention() {
    // 4 "kernel" threads cycle busy_begin/busy_end while a waiter's bound
    // expires repeatedly: the waiter must keep extending, then release when
    // the signal finally lands.
    let board = Arc::new(SignalBoard::new(1));
    std::thread::scope(|s| {
        let b = Arc::clone(&board);
        let waiter = s.spawn(move || {
            b.wait_all(&[0], Duration::from_millis(30), || "stress waiter".into())
        });
        for _ in 0..4 {
            let b = Arc::clone(&board);
            s.spawn(move || {
                for _ in 0..20 {
                    b.busy_begin();
                    std::thread::sleep(Duration::from_millis(2));
                    b.busy_end();
                }
            });
        }
        let b = Arc::clone(&board);
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(120));
            b.set(0);
        });
        waiter.join().unwrap().unwrap();
    });
}

// ---------------------------------------------------------------------------
// engine-level races
// ---------------------------------------------------------------------------

/// All-pairs exchange: every rank pushes its own row to every peer, then
/// waits for every inbound row. Maximally contended transfer traffic with
/// a full wait fan-in per rank.
fn all_pairs_plan(world: usize, t: &TensorTable) -> ExecutablePlan {
    let x = t.lookup("x").unwrap();
    let cols = 4usize;
    let sig = |src: usize, dst: usize| src * world + dst;
    let per_rank = (0..world)
        .map(|r| {
            let mut ops = Vec::new();
            for dst in 0..world {
                if dst != r {
                    ops.push(PlanOp::Issue(transfer_desc(
                        x,
                        Region::rows(r, 1, cols),
                        sig(r, dst),
                        r,
                        dst,
                        vec![],
                        false,
                    )));
                }
            }
            for src in 0..world {
                if src != r {
                    ops.push(PlanOp::Wait(sig(src, r)));
                }
            }
            RankProgram { ops }
        })
        .collect();
    ExecutablePlan { world, per_rank, num_signals: world * world, reserved_comm_sms: 0 }
}

#[test]
fn all_pairs_exchange_races_clean_at_worlds_4_and_8() {
    let rt = Runtime::open_default().unwrap();
    for world in [4usize, 8] {
        let mut t = TensorTable::new();
        t.declare("x", &[world, 4], DType::F32).unwrap();
        let plan = all_pairs_plan(world, &t);
        // 10 fresh runs per world: thread interleavings differ, results must not
        for run in 0..10usize {
            let mut store = BufferStore::new(world);
            store.declare("x", &[world, 4]).unwrap();
            for r in 0..world {
                store.set(r, "x", &vec![(r + 1) as f32; world * 4]).unwrap();
            }
            let opts = ExecOptions {
                mode: ExecMode::Parallel,
                wait_timeout: Duration::from_secs(10),
                ..ExecOptions::parallel()
            };
            let stats = run_with(&plan, &t, &store, &rt, &opts)
                .unwrap_or_else(|e| panic!("world {world} run {run}: {e}"));
            assert_eq!(stats.transfers, world * (world - 1));
            for r in 0..world {
                let v = store.get(r, "x").unwrap();
                for src in 0..world {
                    let want = if src == r { (r + 1) as f32 } else { (src + 1) as f32 };
                    assert_eq!(
                        &v[src * 4..(src + 1) * 4],
                        &[want; 4],
                        "world {world} run {run}: rank {r} row {src}"
                    );
                }
            }
        }
    }
}

#[test]
fn dependent_chains_complete_under_tight_bound_at_world_8() {
    // forwarding chains exercise the parked-transfer path: rank r's send
    // depends on the signal of rank r-1's send, so transfers park and must
    // be drained by their DESTINATION rank as deps land.
    let world = 8usize;
    let mut t = TensorTable::new();
    let x = t.declare("x", &[4, 4], DType::F32).unwrap();
    let rt = Runtime::open_default().unwrap();
    for run in 0..10usize {
        let mut per_rank: Vec<RankProgram> = Vec::new();
        for r in 0..world - 1 {
            let deps = if r == 0 { vec![] } else { vec![r - 1] };
            per_rank.push(RankProgram {
                ops: vec![PlanOp::Issue(transfer_desc(
                    x,
                    Region::rows(0, 2, 4),
                    r,
                    r,
                    r + 1,
                    deps,
                    false,
                ))],
            });
        }
        per_rank.push(RankProgram { ops: vec![PlanOp::Wait(world - 2)] });
        let plan = ExecutablePlan {
            world,
            per_rank,
            num_signals: world - 1,
            reserved_comm_sms: 0,
        };
        let mut store = BufferStore::new(world);
        store.declare("x", &[4, 4]).unwrap();
        store.set(0, "x", &[9.0; 16]).unwrap();
        let opts = ExecOptions {
            mode: ExecMode::Parallel,
            wait_timeout: Duration::from_millis(500),
            ..ExecOptions::parallel()
        };
        let stats = run_with(&plan, &t, &store, &rt, &opts)
            .unwrap_or_else(|e| panic!("run {run}: {e}"));
        assert_eq!(stats.transfers, world - 1);
        assert_eq!(&store.get(world - 1, "x").unwrap()[..8], &[9.0; 8]);
    }
}
