//! Integration: the observability layer end-to-end (DESIGN.md §16).
//!
//! Three claims:
//!
//! 1. **Repeat loops feed histograms** — replaying a prepared plan through
//!    the arena-reusing engine lands every iteration in an `exec.iter_us`
//!    histogram whose percentiles are derivable without allocation.
//! 2. **Snapshots survive export** — a registry snapshot round-trips
//!    through both wire formats (`syncopate.stats.v1` JSON and Prometheus
//!    text exposition) without losing counts, bounds, or label structure.
//! 3. **The serving path is instrumented** — a worker pool serving user
//!    plans populates per-phase latency histograms, request counters,
//!    cache counters, and returns the queue-depth gauge to zero.
//!
//! The registry is process-global and this binary's tests share it, so
//! cross-cutting metrics (queue depth, cache counters) are asserted as
//! deltas; exactness is reserved for metric keys unique to a single test.

use std::path::Path;

use syncopate::coordinator::execases;
use syncopate::coordinator::service::Coordinator;
use syncopate::exec::{prepare, run_prepared_reusing, ExecOptions, PlanArena};
use syncopate::obs::{self, export};
use syncopate::runtime::Runtime;

#[test]
fn repeat_loop_feeds_exec_histograms() {
    let rt = Runtime::open_default().unwrap();
    let case = execases::ag_gemm(2, 2, 7).unwrap();
    let prep = prepare(&case.plan, &case.sched.tensors).unwrap();
    let mut arena = PlanArena::new(&prep);
    // key unique to this test -> exact assertions are safe
    let hist = obs::histogram_with("exec.iter_us", &[("case", "obs-itest")]);
    let opts = ExecOptions::parallel();
    const N: usize = 5;
    for _ in 0..N {
        let t0 = std::time::Instant::now();
        run_prepared_reusing(&prep, &mut arena, &case.store, &rt, &opts).unwrap();
        hist.record_us(obs::us_since(t0));
    }
    let s = hist.snap();
    assert_eq!(s.count, N as u64, "every iteration must be recorded");
    assert_eq!(s.count, s.buckets.iter().sum::<u64>());
    let (p50, p99) = (s.percentile(0.50), s.percentile(0.99));
    assert!(p50.is_finite() && p99.is_finite());
    assert!(p50 <= p99 && p99 <= s.max_us.max(1.0) * 2.0, "p50 {p50} p99 {p99}");
    assert!(s.sum_us > 0.0 && s.max_us > 0.0);
    // the snapshot surfaces the same histogram under its labeled key
    let snap = obs::registry().snapshot();
    let got = snap
        .histogram("exec.iter_us", &[("case", "obs-itest")])
        .expect("repeat histogram must appear in the registry snapshot");
    assert!(got.count >= N as u64);
}

#[test]
fn snapshot_round_trips_through_both_wire_formats() {
    // unique names so the values are exact regardless of sibling tests
    obs::counter("itest.round_trip_total").add(42);
    obs::gauge_with("itest.depth", &[("lane", "a")]).set(3.25);
    let h = obs::histogram("itest.lat_us");
    for us in [0.5, 3.0, 17.0, 900.0, 123456.0] {
        h.record_us(us);
    }
    let snap = obs::registry().snapshot();

    // JSON: schema-tagged, parseable, value-preserving
    let json = export::to_json(&snap);
    export::check_schema(&json).expect("our own snapshot must satisfy the schema");
    let back = export::from_json(&json).unwrap();
    assert!(back.counter("itest.round_trip_total", &[]).unwrap() >= 42);
    assert_eq!(back.gauge("itest.depth", &[("lane", "a")]), Some(3.25));
    let (orig, rt) = (
        snap.histogram("itest.lat_us", &[]).unwrap(),
        back.histogram("itest.lat_us", &[]).unwrap(),
    );
    assert_eq!(orig.count, rt.count);
    assert_eq!(orig.buckets, rt.buckets);
    assert_eq!(orig.max_us, rt.max_us);
    assert!((orig.percentile(0.9) - rt.percentile(0.9)).abs() < 1e-9);

    // Prometheus: every flattened scalar appears, parse(render) stable
    let prom = export::to_prometheus(&snap);
    let parsed = export::parse_prometheus(&prom).unwrap();
    assert!(!parsed.is_empty());
    let find = |name: &str| {
        parsed
            .iter()
            .find(|(k, _)| k.contains(name))
            .unwrap_or_else(|| panic!("{name} missing from exposition:\n{prom}"))
            .1
    };
    assert!(find("itest_round_trip_total") >= 42.0);
    assert_eq!(find("itest_depth"), 3.25);
    assert!(find("itest_lat_us_count") >= 5.0);
}

#[test]
fn serve_pool_populates_phase_histograms_and_drains_queue() {
    let text = std::fs::read_to_string(
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/plans/hetero_fig4e_2x2.sched"),
    )
    .unwrap();
    let snap0 = obs::registry().snapshot();
    let count0 = |name: &str, labels: &[(&str, &str)]| {
        snap0.histogram(name, labels).map(|h| h.count).unwrap_or(0)
    };
    let req0 = count0("serve.request_us", &[("kind", "user-plan")]);
    let parse0 = count0("serve.phase_us", &[("phase", "parse")]);
    let exec0 = count0("serve.phase_us", &[("phase", "exec")]);
    let tune0 = count0("serve.phase_us", &[("phase", "tune")]);

    let coord =
        Coordinator::spawn_pool(syncopate::hw::catalog::topology("h100_node", 4).unwrap(), 4);
    let cold = coord.run_user_plan(&text, ExecOptions::parallel()).unwrap();
    let warm = coord.run_user_plan(&text, ExecOptions::parallel()).unwrap();
    assert!(!cold.cache_hit && warm.cache_hit);

    let snap = obs::registry().snapshot();
    let count = |name: &str, labels: &[(&str, &str)]| {
        snap.histogram(name, labels).map(|h| h.count).unwrap_or(0)
    };
    // both requests timed end-to-end and in every always-on phase
    assert!(count("serve.request_us", &[("kind", "user-plan")]) >= req0 + 2);
    assert!(count("serve.phase_us", &[("phase", "parse")]) >= parse0 + 2);
    assert!(count("serve.phase_us", &[("phase", "exec")]) >= exec0 + 2);
    // tune runs on the cold path only; the warm hit skips it
    assert!(count("serve.phase_us", &[("phase", "tune")]) >= tune0 + 1);
    let p99 = snap
        .histogram("serve.request_us", &[("kind", "user-plan")])
        .unwrap()
        .percentile(0.99);
    assert!(p99.is_finite() && p99 > 0.0);

    // the pool went idle: queue drained, no worker mid-request
    assert_eq!(snap.gauge("coord.queue_depth", &[]), Some(0.0));
    let served: u64 = (0..4)
        .filter_map(|w| {
            let wl = w.to_string();
            snap.counter("coord.worker_requests", &[("worker", wl.as_str())])
        })
        .sum();
    assert!(served >= 2, "pool workers must count served requests, got {served}");

    // the plan cache saw one miss (cold) then one hit (warm)
    let shard_sum = |name: &str| -> u64 {
        snap.entries
            .iter()
            .filter(|(k, _)| k.name == name)
            .filter_map(|(_, v)| match v {
                obs::Value::Counter(c) => Some(*c),
                _ => None,
            })
            .sum()
    };
    let hits0: u64 = snap0
        .entries
        .iter()
        .filter(|(k, _)| k.name == "plan_cache.hits")
        .filter_map(|(_, v)| match v {
            obs::Value::Counter(c) => Some(*c),
            _ => None,
        })
        .sum();
    assert!(shard_sum("plan_cache.hits") >= hits0 + 1);
    assert!(shard_sum("plan_cache.misses") >= 1);

    // -- traced serving feeds the standing sim-vs-trace divergence gauge.
    // (Same test fn as the pool above so all coordinator traffic in this
    // binary is serialized: the queue-depth-zero assertion cannot race
    // against another test's in-flight request.)
    let traced = std::fs::read_to_string(
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/plans/neighbor_first_w4.sched"),
    )
    .unwrap();
    let samples0 = obs::counter("sim.divergence_samples").get();
    let r = coord.run_user_plan_traced(&traced, ExecOptions::parallel()).unwrap();
    assert!(r.trace.is_some(), "traced serving must return overlap stats");
    assert!(
        obs::counter("sim.divergence_samples").get() >= samples0 + 1,
        "every traced run must sample the divergence gauge"
    );
    let snap = obs::registry().snapshot();
    let g = snap.gauge("sim.divergence", &[]).expect("divergence gauge must exist");
    assert!(g.is_finite(), "divergence gauge must hold a real ratio, got {g}");
}
