//! Integration: the flight recorder end-to-end (DESIGN.md §18).
//!
//! Four claims:
//!
//! 1. **Snapshots never tear** — concurrent writers hammering one rank's
//!    ring while snapshots drain it can lose slots (counted, acceptable)
//!    but never surface a slot mixing two writers' words.
//! 2. **Overwrite-oldest preserves order** — flooding a ring past its
//!    capacity keeps the newest window, still in per-rank issue order.
//! 3. **Deadlock accounting is exactly-once** — all three engines
//!    (sequential, parallel/atomic, parallel/condvar) bump
//!    `error_total{kind=deadlock}` exactly once per verdict, the verdict
//!    carries the stuck ranks' recent flight events, the configured dump
//!    file is written, and served errors carry their request ID.
//! 4. **Dumps round-trip** — `from_json(to_json(dump)) == dump` for a
//!    snapshot of real recorded events.
//!
//! Ring lanes are keyed by rank (`rank & 0xF`): tests in this binary that
//! write events directly use ranks 12–15 so they cannot collide with the
//! engine runs (world 2 → lanes 0/1) or each other. The deadlock/serving
//! assertions share one test fn so the process-global deadlock counter and
//! dump path are never raced by a sibling test.

use std::time::Duration;

use syncopate::coordinator::execases;
use syncopate::coordinator::service::Coordinator;
use syncopate::exec::{run_with, ExecOptions, SyncStrategy};
use syncopate::obs::{self, flight};
use syncopate::runtime::Runtime;

#[test]
fn concurrent_writers_never_tear_a_snapshot() {
    // 4 writers record rank-15 events whose two payload words agree
    // (a == b); a torn read would decode a slot mixing two writers'
    // words and break the equality. Snapshots run while they write.
    const WRITES: usize = 4096;
    std::thread::scope(|s| {
        for t in 0..4usize {
            s.spawn(move || {
                for i in 0..WRITES {
                    let v = (t * WRITES + i) % 0x8000; // fits the u16 b field
                    flight::signal_wait(15, v, v);
                }
            });
        }
        for _ in 0..8 {
            let dump = flight::snapshot("tear-test");
            for e in dump.events.iter().filter(|e| e.rank == 15) {
                assert_eq!(e.code, flight::SIGNAL_WAIT);
                assert_eq!(e.a, e.b as u32, "torn slot surfaced: {e:?}");
            }
        }
    });
    // the final quiescent snapshot holds a full, coherent window
    let dump = flight::snapshot("tear-test-final");
    let n = dump.events.iter().filter(|e| e.rank == 15).count();
    assert_eq!(n, flight::RING_CAPACITY, "quiescent ring must drain full");
}

#[test]
fn overwrite_oldest_keeps_per_rank_order() {
    const TOTAL: usize = 3 * flight::RING_CAPACITY;
    for i in 0..TOTAL {
        flight::op_issue(12, i);
    }
    let dump = flight::snapshot("overwrite-test");
    let seen: Vec<u32> =
        dump.events.iter().filter(|e| e.rank == 12).map(|e| e.a).collect();
    assert!(!seen.is_empty());
    assert!(seen.len() <= flight::RING_CAPACITY);
    assert!(
        seen.windows(2).all(|w| w[0] < w[1]),
        "per-rank order must survive overwrite: {seen:?}"
    );
    // oldest events were overwritten, newest survived
    assert_eq!(*seen.last().unwrap() as usize, TOTAL - 1);
    assert!(seen[0] as usize >= TOTAL - flight::RING_CAPACITY);
}

#[test]
fn deadlock_counted_once_per_engine_with_dump_and_request_ids() {
    let rt = Runtime::open_default().unwrap();
    let deadlocks = || obs::counter_with("error_total", &[("kind", "deadlock")]).get();

    // arm the post-mortem dump path for the engine runs below
    let path = std::env::temp_dir()
        .join(format!("syncopate-flight-itest-{}.json", std::process::id()));
    flight::set_dump_path(path.to_str());

    let engines: [(&str, ExecOptions); 3] = [
        ("sequential", ExecOptions::sequential()),
        (
            "parallel/atomic",
            ExecOptions {
                wait_timeout: Duration::from_millis(100),
                ..ExecOptions::parallel()
            },
        ),
        (
            "parallel/condvar",
            ExecOptions {
                wait_timeout: Duration::from_millis(100),
                sync: SyncStrategy::Condvar,
                ..ExecOptions::parallel()
            },
        ),
    ];
    for (tag, opts) in engines {
        let case = execases::deadlock_demo(2).unwrap();
        let before = deadlocks();
        let e = run_with(&case.plan, &case.sched.tensors, &case.store, &rt, &opts)
            .unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("deadlock"), "{tag}: {msg}");
        // the verdict names the stuck ranks' recent history
        assert!(msg.contains("recent flight events"), "{tag}: {msg}");
        assert!(msg.contains("sig-wait"), "{tag}: {msg}");
        assert_eq!(
            deadlocks(),
            before + 1,
            "{tag}: deadlock must be counted exactly once"
        );
    }
    flight::set_dump_path(None);

    // every verdict overwrote the configured dump; the survivor is a
    // valid schema-tagged capture of the last one
    let text = std::fs::read_to_string(&path).expect("deadlock verdict must write the dump");
    let n = flight::check_schema(&text).unwrap();
    assert!(n > 0, "dump must carry events");
    let dump = flight::from_json(&text).unwrap();
    assert_eq!(dump.reason, "deadlock");
    assert!(dump.events.iter().any(|e| e.code == flight::SIGNAL_WAIT));
    let _ = std::fs::remove_file(&path);

    // served errors carry the request ID in front of the real failure
    let coord =
        Coordinator::spawn_pool(syncopate::hw::catalog::topology("h100_node", 4).unwrap(), 1);
    let e = coord
        .run_user_plan("definitely not a schedule", ExecOptions::parallel())
        .unwrap_err();
    let msg = e.to_string();
    let at = msg.find("request ").unwrap_or_else(|| panic!("no request id in: {msg}"));
    assert!(
        msg[at + "request ".len()..].starts_with(|c: char| c.is_ascii_digit()),
        "request prefix must carry a numeric id: {msg}"
    );
    // the original failure class survives behind the prefix
    assert!(msg.contains("line 1"), "{msg}");
}

#[test]
fn snapshot_round_trips_through_flight_json() {
    flight::op_apply(13, 7, 3);
    flight::queue_drain(13, 2);
    let dump = flight::snapshot("round-trip-test");
    assert!(dump.events.iter().any(|e| e.rank == 13));
    let back = flight::from_json(&flight::to_json(&dump)).unwrap();
    assert_eq!(back, dump, "flight JSON must round-trip exactly");
}

/// Under `--features no-obs` the record fns compile to empty bodies: the
/// rings stay empty no matter how much the hot path "records".
#[cfg(feature = "no-obs")]
#[test]
fn no_obs_build_records_nothing() {
    flight::op_issue(14, 1);
    flight::signal_wait(14, 2, 3);
    flight::queue_drain(14, 4);
    let dump = flight::snapshot("no-obs-test");
    assert!(dump.events.iter().all(|e| e.rank != 14));
    assert!(flight::last_events(14, 8).is_empty());
}
