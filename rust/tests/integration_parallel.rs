//! Integration: the parallel per-rank engine against the sequential
//! reference interpreter.
//!
//! Three claims (DESIGN.md §6):
//!
//! 1. **Bit-identity** — for every schedule template and world size, both
//!    engines produce bit-identical f32 state on every rank (the
//!    deterministic reduction order makes true concurrency reproducible).
//! 2. **Bounded-wait deadlock detection** — a cyclic schedule returns an
//!    `Error` from the parallel engine within the configured bound instead
//!    of hanging.
//! 3. **Oracle correctness** — both runs are additionally checked against
//!    the host oracles, so a template wrong in *both* engines still fails.

use std::time::{Duration, Instant};

use syncopate::chunk::{DType, Region, TensorTable};
use syncopate::codegen::{ExecutablePlan, PlanOp, RankProgram, TransferDesc};
use syncopate::coordinator::execases::{
    self, verify_modes_bit_identical, verify_sync_strategies_bit_identical, AgVariant,
    CaseParams, ExecCase,
};
use syncopate::exec::{run_with, BufferStore, ExecMode, ExecOptions, SyncStrategy};
use syncopate::runtime::Runtime;
use syncopate::testutil::transfer_desc;
use syncopate::Result;

fn rt() -> Runtime {
    Runtime::open_default().expect("open_default falls back to host-ref; cannot fail")
}

fn check(rt: &Runtime, build: &dyn Fn() -> Result<ExecCase>) {
    // error messages out of verify_modes_bit_identical carry the case name
    verify_modes_bit_identical(build, rt).unwrap_or_else(|e| panic!("cross-mode: {e}"));
}

#[test]
fn ag_gemm_all_variants_bit_identical() {
    // AllGather as pull swizzle, push ring (forwarding dep chains), and
    // push direct — every variant, every world size.
    let rt = rt();
    for world in [2usize, 4, 8] {
        for variant in [AgVariant::PullSwizzle, AgVariant::PushRing, AgVariant::PushDirect] {
            check(&rt, &move || execases::ag_gemm_variant(world, 1, 42 + world as u64, variant));
        }
    }
}

#[test]
fn ag_gemm_split_subchunks_bit_identical() {
    let rt = rt();
    for split in [2usize, 4] {
        check(&rt, &move || execases::ag_gemm(4, split, 99));
    }
    check(&rt, &|| execases::ag_gemm_variant(4, 2, 808, AgVariant::PushRing));
}

#[test]
fn gemm_reduce_scatter_bit_identical() {
    // reduce transfers into the same shard MUST land in canonical order in
    // the parallel engine — this is the test that catches f32
    // non-associativity races.
    let rt = rt();
    for world in [2usize, 4, 8] {
        check(&rt, &move || execases::gemm_rs(world, 100 + world as u64));
    }
}

#[test]
fn gemm_all_reduce_bit_identical() {
    let rt = rt();
    for world in [2usize, 4, 8] {
        check(&rt, &move || execases::gemm_ar(world, 200 + world as u64));
    }
}

#[test]
fn a2a_gemm_bit_identical() {
    let rt = rt();
    for world in [2usize, 4, 8] {
        check(&rt, &move || execases::a2a_gemm(world, 300 + world as u64));
    }
}

#[test]
fn ring_attention_bit_identical() {
    let rt = rt();
    for world in [2usize, 4, 8] {
        check(&rt, &move || execases::ring_attention(world, 1, 400 + world as u64));
    }
    check(&rt, &|| execases::ring_attention(4, 2, 444));
}

#[test]
fn attn_sp_bit_identical() {
    let rt = rt();
    for world in [2usize, 4, 8] {
        check(&rt, &move || execases::attn_sp(world, 500 + world as u64));
    }
}

#[test]
fn imported_plans_bit_identical() {
    // plans ported from stream-level baseline descriptions (plan_io::import)
    // must execute with the same cross-engine bit-identity guarantee as
    // native templates — the ISSUE 2 "ported plans execute" criterion.
    let rt = rt();
    for world in [2usize, 4, 8] {
        for variant in [AgVariant::ImportedFlux, AgVariant::ImportedTritonDist] {
            check(&rt, &move || {
                execases::ag_gemm_variant(world, 1, 600 + world as u64, variant)
            });
        }
    }
    // the split knob composes with imported chunking
    check(&rt, &|| execases::ag_gemm_variant(4, 2, 606, AgVariant::ImportedFlux));
}

#[test]
fn hierarchical_ag_gemm_bit_identical() {
    // the two-level mesh template needs >= 2 ranks per node: worlds 4 and 8
    let rt = rt();
    for (nodes, rpn) in [(2usize, 2usize), (2, 4)] {
        check(&rt, &move || execases::ag_gemm_hierarchical(nodes, rpn, 77));
    }
}

#[test]
fn every_registry_case_tri_engine_bit_identical() {
    // the lock-free hot path's safety net: EVERY registered exec case, at
    // every world size it supports, must produce bit-identical f32 state
    // from the sequential reference, the atomic parallel engine, and the
    // retained condvar parallel engine.
    let rt = rt();
    let mut verified = 0usize;
    for spec in execases::CASES {
        for world in [2usize, 4, 8] {
            let params = CaseParams { world, ..Default::default() };
            // some cases reject some shapes (e.g. hierarchical needs >= 2
            // ranks per node): a named build error is a skip, not a failure
            if spec.build(&params).is_err() {
                continue;
            }
            verify_sync_strategies_bit_identical(&|| spec.build(&params), &rt)
                .unwrap_or_else(|e| panic!("{} w{world}: {e}", spec.name));
            verified += 1;
        }
    }
    assert!(verified >= 20, "registry sweep degenerated: only {verified} case-worlds ran");
}

// ---------------------------------------------------------------------------
// deadlock detection
// ---------------------------------------------------------------------------

fn call_free_fixture() -> (TensorTable, BufferStore) {
    let mut t = TensorTable::new();
    t.declare("x", &[4, 4], DType::F32).unwrap();
    let mut s = BufferStore::new(2);
    s.declare("x", &[4, 4]).unwrap();
    (t, s)
}

fn xfer(t: &TensorTable, signal: usize, src: usize, dst: usize, deps: Vec<usize>) -> TransferDesc {
    let id = t.lookup("x").unwrap();
    transfer_desc(id, Region::rows(0, 2, 4), signal, src, dst, deps, false)
}

fn short_parallel() -> ExecOptions {
    ExecOptions {
        mode: ExecMode::Parallel,
        wait_timeout: Duration::from_millis(250),
        ..ExecOptions::parallel()
    }
}

fn short_parallel_sync(sync: SyncStrategy) -> ExecOptions {
    ExecOptions { sync, ..short_parallel() }
}

#[test]
fn cyclic_issue_schedule_errors_within_bound() {
    // T0 (rank0->1) depends on signal 1; T1 (rank1->0) depends on signal 0:
    // a dependency cycle between transfers. Structural validation cannot see
    // it (both signals have producers); the engines must catch it at run
    // time — the parallel one within the bounded wait, not by hanging.
    let (t, _store) = call_free_fixture();
    let plan = ExecutablePlan {
        world: 2,
        per_rank: vec![
            RankProgram { ops: vec![PlanOp::Issue(xfer(&t, 0, 0, 1, vec![1]))] },
            RankProgram { ops: vec![PlanOp::Issue(xfer(&t, 1, 1, 0, vec![0]))] },
        ],
        num_signals: 2,
        reserved_comm_sms: 0,
    };
    let rt = rt();

    // both parallel synchronization cores must report the same verdict
    for sync in [SyncStrategy::Atomic, SyncStrategy::Condvar] {
        let (t, store) = call_free_fixture();
        let t0 = Instant::now();
        let e = run_with(&plan, &t, &store, &rt, &short_parallel_sync(sync)).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(20), "bounded wait must bound the wait");
        assert!(e.to_string().contains("deadlock"), "{sync:?}: {e}");
    }

    // the sequential reference engine agrees (and detects it exactly)
    let (t, store) = call_free_fixture();
    let e = run_with(&plan, &t, &store, &rt, &ExecOptions::sequential()).unwrap_err();
    assert!(e.to_string().contains("deadlock"), "{e}");
}

#[test]
fn cyclic_wait_schedule_errors_within_bound() {
    // rank0 waits for rank1's transfer before issuing its own, and vice
    // versa: both rank threads block in Wait forever.
    let (t, store) = call_free_fixture();
    let plan = ExecutablePlan {
        world: 2,
        per_rank: vec![
            RankProgram {
                ops: vec![PlanOp::Wait(1), PlanOp::Issue(xfer(&t, 0, 0, 1, vec![]))],
            },
            RankProgram {
                ops: vec![PlanOp::Wait(0), PlanOp::Issue(xfer(&t, 1, 1, 0, vec![]))],
            },
        ],
        num_signals: 2,
        reserved_comm_sms: 0,
    };
    let rt = rt();
    for sync in [SyncStrategy::Atomic, SyncStrategy::Condvar] {
        let t0 = Instant::now();
        let e = run_with(&plan, &t, &store, &rt, &short_parallel_sync(sync)).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(20));
        assert!(e.to_string().contains("deadlock"), "{sync:?}: {e}");
        assert!(e.to_string().contains("rank"), "stuck rank should be named: {e}");
    }
}

#[test]
fn forwarding_chain_completes_under_short_bound() {
    // a long parked-transfer chain where every hop is legitimate must
    // complete under a short bound (each hop is serviced as its dep
    // lands). NOTE: hops here are fast, so the bound-resets-on-progress
    // property itself (a slow hop exceeding the bound while the run is
    // live) is pinned by the timing-controlled unit tests in
    // exec::signals (activity_resets_the_bound,
    // busy_work_defers_the_verdict), not by this test.
    let mut t = TensorTable::new();
    let x = t.declare("x", &[4, 4], DType::F32).unwrap();
    let world = 8usize;
    let mut s = BufferStore::new(world);
    s.declare("x", &[4, 4]).unwrap();
    s.set(0, "x", &[3.0; 16]).unwrap();
    let mk = |signal: usize, src: usize, dst: usize, deps: Vec<usize>| {
        transfer_desc(x, Region::rows(0, 2, 4), signal, src, dst, deps, false)
    };
    let mut per_rank: Vec<RankProgram> = Vec::new();
    for r in 0..world - 1 {
        let deps = if r == 0 { vec![] } else { vec![r - 1] };
        per_rank.push(RankProgram { ops: vec![PlanOp::Issue(mk(r, r, r + 1, deps))] });
    }
    per_rank.push(RankProgram { ops: vec![PlanOp::Wait(world - 2)] });
    let plan = ExecutablePlan {
        world,
        per_rank,
        num_signals: world - 1,
        reserved_comm_sms: 0,
    };
    let rt = rt();
    let stats = run_with(&plan, &t, &s, &rt, &short_parallel()).unwrap();
    assert_eq!(stats.transfers, world - 1);
    assert_eq!(&s.get(world - 1, "x").unwrap()[..8], &[3.0; 8]);
}
