//! Integration: the coordinator service loop (tune-once, run-many) and the
//! annotated-kernel frontend wired to the shipped Pallas sources.

use std::collections::HashMap;
use std::path::Path;

use syncopate::coordinator::service::{opkind_by_name, Coordinator, Request};
use syncopate::coordinator::TuneConfig;
use syncopate::kernel::annotations::parse_annotations_file;
use syncopate::workload::{OpKind, OperatorInstance, LLAMA3_70B, LLAMA3_8B};

#[test]
fn service_runs_the_operator_registry() {
    let coord = Coordinator::spawn(syncopate::hw::catalog::topology("h100_node", 8).unwrap());
    for name in ["ag-gemm", "gemm-rs", "gemm-ar"] {
        let kind = opkind_by_name(name).unwrap();
        let op = OperatorInstance::gemm(kind, &LLAMA3_8B, 8192, 8);
        let cfg = match kind {
            OpKind::GemmRs | OpKind::GemmAr => TuneConfig {
                real: syncopate::codegen::Realization::new(
                    syncopate::backend::BackendKind::LdStSpecialized,
                    32,
                ),
                ..Default::default()
            },
            _ => TuneConfig::default(),
        };
        let r = coord.run(op, cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(r.tflops > 1.0, "{name}");
    }
}

#[test]
fn plan_cache_hits_on_repeat_requests() {
    let coord = Coordinator::spawn(syncopate::hw::catalog::topology("h100_node", 4).unwrap());
    let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, 8192, 4);
    let a = coord.run(op, TuneConfig::default()).unwrap();
    let b = coord.run(op, TuneConfig::default()).unwrap();
    assert!(!a.cache_hit && b.cache_hit);
    assert_eq!(a.makespan_us, b.makespan_us);
    // a different config misses
    let c = coord.run(op, TuneConfig { split: 4, ..Default::default() }).unwrap();
    assert!(!c.cache_hit);
}

#[test]
fn user_plan_serves_shipped_corpus_through_cached_path() {
    // A schedule authored purely in the textual DSL (no Rust) runs
    // end-to-end: validate -> restricted autotune -> codegen -> exec,
    // cached under the content hash of the canonical printed form.
    use syncopate::exec::ExecOptions;
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/plans/hetero_fig4e_2x2.sched");
    let text = std::fs::read_to_string(&path).unwrap();
    let coord = Coordinator::spawn_pool(syncopate::hw::catalog::topology("h100_node", 4).unwrap(), 2);
    let cold = coord.run_user_plan(&text, ExecOptions::parallel()).unwrap();
    assert!(!cold.cache_hit);
    assert_eq!(cold.world, 4);
    assert_eq!(cold.ops, 12);
    assert_eq!(cold.stats.transfers, 12);
    let warm = coord.run_user_plan(&text, ExecOptions::sequential()).unwrap();
    assert!(warm.cache_hit, "re-serving the same plan must hit the cache");
    assert_eq!(warm.hash, cold.hash);
    // both engines moved identical bytes over the same cached plan
    assert_eq!(warm.stats.transfers, cold.stats.transfers);
    assert_eq!(warm.stats.bytes_moved, cold.stats.bytes_moved);
}

#[test]
fn pipelined_submissions_all_answer() {
    let coord = Coordinator::spawn(syncopate::hw::catalog::topology("h100_node", 8).unwrap());
    let mut rxs = Vec::new();
    for tokens in [2048usize, 4096, 8192] {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, tokens, 8);
        rxs.push((tokens, coord.submit(Request::Run { op, cfg: TuneConfig::default() }).unwrap()));
    }
    let mut prev = 0.0;
    for (tokens, rx) in rxs {
        let r = rx.recv().unwrap().unwrap();
        assert!(r.makespan_us >= prev, "tokens {tokens} out of order");
        prev = r.makespan_us;
    }
}

#[test]
fn annotated_pallas_sources_drive_the_grid() {
    // the Rust frontend parses the SAME kernel files the AOT path compiles
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let gemm = parse_annotations_file(&root.join("python/compile/kernels/gemm.py")).unwrap();
    let sizes: HashMap<String, usize> =
        [("M".to_string(), 8192), ("N".to_string(), 1792), ("K".to_string(), 4096)].into();
    let grid = gemm.to_grid(&sizes, &HashMap::new()).unwrap();
    assert_eq!(grid.axes.len(), 3);
    assert_eq!(grid.axes[0].block, 128); // BLOCK_M from the python source
    assert_eq!(grid.num_tiles(), 64 * 14 * 32);

    let attn =
        parse_annotations_file(&root.join("python/compile/kernels/attention.py")).unwrap();
    assert_eq!(attn.axes[0].0, "Q");
    let sizes: HashMap<String, usize> = [("Q".to_string(), 4096)].into();
    let agrid = attn.to_grid(&sizes, &HashMap::new()).unwrap();
    assert_eq!(agrid.axes[0].block, 64); // BLOCK_Q
}

#[test]
fn pool_stress_concurrent_clients_cache_accounting_consistent() {
    // N client threads x M requests against a 4-worker pool over a small
    // set of distinct configurations. Checks: every request answers, answers
    // are deterministic per key, and cache-hit accounting stays consistent
    // (hits + misses == total; per key at least one miss, and never more
    // misses than workers — the bounded compile race).
    use std::sync::Mutex;

    let workers = 4usize;
    let coord = Coordinator::spawn_pool(syncopate::hw::catalog::topology("h100_node", 4).unwrap(), workers);
    let tokens_keys = [2048usize, 4096, 8192, 16384];
    let results: Mutex<Vec<(usize, bool, f64)>> = Mutex::new(Vec::new());

    std::thread::scope(|s| {
        for t in 0..6usize {
            let client = coord.client();
            let results = &results;
            s.spawn(move || {
                for i in 0..12usize {
                    let tokens = tokens_keys[(t + i) % tokens_keys.len()];
                    let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, tokens, 4);
                    let r = client.run(op, TuneConfig::default()).unwrap();
                    results.lock().unwrap().push((tokens, r.cache_hit, r.makespan_us));
                }
            });
        }
    });

    let results = results.into_inner().unwrap();
    assert_eq!(results.len(), 6 * 12);
    for &tokens in &tokens_keys {
        let per_key: Vec<_> = results.iter().filter(|r| r.0 == tokens).collect();
        let misses = per_key.iter().filter(|r| !r.1).count();
        assert!(misses >= 1, "tokens {tokens}: someone must have compiled it");
        assert!(
            misses <= workers,
            "tokens {tokens}: {misses} misses > {workers} workers — cache is not shared"
        );
        let t0 = per_key[0].2;
        assert!(
            per_key.iter().all(|r| r.2 == t0),
            "tokens {tokens}: answers diverge across workers"
        );
    }
    // cache is warm: a fresh request on any key must hit
    for &tokens in &tokens_keys {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, tokens, 4);
        assert!(coord.run(op, TuneConfig::default()).unwrap().cache_hit);
    }
}

#[test]
fn errors_surface_through_the_service() {
    let coord = Coordinator::spawn(syncopate::hw::catalog::topology("h100_node", 4).unwrap());
    // reduce on the default copy-engine realization is infeasible
    let op = OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_8B, 8192, 4);
    let e = coord.run(op, TuneConfig::default()).unwrap_err();
    assert_eq!(e.subsystem(), "backend");
}
