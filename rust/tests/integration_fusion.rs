//! Integration: cross-operator pipeline fusion (ISSUE 3 acceptance).
//!
//! * `tp-block` (AG-GEMM → GEMM-RS) and `moe-a2a` (A2A dispatch → expert
//!   GEMMs → A2A combine) execute with real numerics, bit-identically on
//!   the sequential and parallel engines, at worlds 2/4/8;
//! * `reports::pipeline` shows the fused makespan strictly below the
//!   barrier-at-boundary baseline (sum of per-stage makespans) for both
//!   cases at every world size;
//! * fused pipelines ride the PR-2 interchange: they print/parse through
//!   `plan_io` and serve through the coordinator's content-hash plan
//!   cache, with the two-formats-one-entry property intact.

use syncopate::coordinator::execases::{self, verify_modes_bit_identical, ExecCase};
use syncopate::coordinator::service::Coordinator;
use syncopate::exec::ExecOptions;
use syncopate::plan_io::{content_hash, parse_schedule, print_schedule, registry};
use syncopate::reports;
use syncopate::runtime::Runtime;
use syncopate::schedule::validate::validate;
use syncopate::Result;

fn rt() -> Runtime {
    Runtime::open_default().expect("open_default falls back to host-ref; cannot fail")
}

fn check(rt: &Runtime, build: &dyn Fn() -> Result<ExecCase>) {
    verify_modes_bit_identical(build, rt).unwrap_or_else(|e| panic!("cross-mode: {e}"));
}

#[test]
fn tp_block_bit_identical_across_engines() {
    let rt = rt();
    for world in [2usize, 4, 8] {
        check(&rt, &move || execases::tp_block(world, 1, 700 + world as u64));
    }
    // the split knob composes with fusion
    check(&rt, &|| execases::tp_block(4, 2, 707));
}

#[test]
fn moe_a2a_bit_identical_across_engines() {
    let rt = rt();
    for world in [2usize, 4, 8] {
        check(&rt, &move || execases::moe_a2a(world, 800 + world as u64));
    }
}

#[test]
fn report_pipeline_fused_strictly_beats_barrier() {
    // the acceptance criterion: fused makespan strictly below the
    // barrier-at-boundary baseline for BOTH cases at worlds 2/4/8
    let t = reports::pipeline().unwrap();
    assert_eq!(t.rows.len(), 6, "2 cases x 3 world sizes");
    for (label, row) in &t.rows {
        let (fused, barrier, speedup) = (row[0], row[1], row[2]);
        assert!(fused > 0.0, "{label}: degenerate fused makespan {fused}");
        assert!(
            fused < barrier,
            "{label}: fused {fused} us must be strictly below barrier {barrier} us"
        );
        assert!(speedup > 1.0, "{label}: speedup {speedup}");
    }
}

#[test]
fn fused_registry_sources_roundtrip_and_validate() {
    // fused pipelines are plain CommSchedules: they must ride the PR-2
    // interchange untouched (the corpus test also sweeps them; this pins
    // the fused-specific sources explicitly)
    for name in ["tp-block", "moe-a2a"] {
        for world in [2usize, 4, 8] {
            let s = registry::build(name, world)
                .unwrap_or_else(|e| panic!("{name} @ {world}: {e}"));
            validate(&s).unwrap_or_else(|e| panic!("{name} @ {world}: {e}"));
            let printed = print_schedule(&s).unwrap();
            assert_eq!(parse_schedule(&printed).unwrap(), s, "{name} @ {world}");
        }
    }
}

#[test]
fn fused_plans_serve_and_cache_by_content_hash() {
    // ISSUE 3 satellite: plan-cache behavior under pipelines — fused-plan
    // hits/misses keyed by the canonical-form content hash, including the
    // two-formats-one-entry property PR 2 established for user plans.
    let world = 2usize;
    let coord = Coordinator::spawn_pool(syncopate::hw::catalog::topology("h100_node", world).unwrap(), 2);
    let opts = ExecOptions::sequential();

    let text = print_schedule(&registry::build("tp-block", world).unwrap()).unwrap();
    let r1 = coord.run_user_plan(&text, opts.clone()).unwrap();
    assert!(!r1.cache_hit, "first serve must miss");
    assert_eq!(r1.world, world);
    assert_eq!(r1.hash, content_hash(&text), "cache key is the canonical-form hash");

    let r2 = coord.run_user_plan(&text, opts.clone()).unwrap();
    assert!(r2.cache_hit, "re-serving the same fused plan must hit");
    assert_eq!(r2.hash, r1.hash);
    assert_eq!(r2.sim_makespan_us, r1.sim_makespan_us);

    // differently formatted text of the SAME fused plan shares the entry
    let messy = text.replace("  pull", "   pull ").replace("  push", "    push  ");
    assert_ne!(messy, text);
    let r3 = coord.run_user_plan(&messy, opts.clone()).unwrap();
    assert!(r3.cache_hit, "canonical-form hashing must dedupe formatting");
    assert_eq!(r3.hash, r1.hash);

    // a different fused pipeline is a different entry
    let other = print_schedule(&registry::build("moe-a2a", world).unwrap()).unwrap();
    let r4 = coord.run_user_plan(&other, opts.clone()).unwrap();
    assert!(!r4.cache_hit, "distinct fused plans must not collide");
    assert_ne!(r4.hash, r1.hash);

    // and the parallel engine serves the cached fused plan too
    let r5 = coord.run_user_plan(&text, ExecOptions::parallel()).unwrap();
    assert!(r5.cache_hit);
    assert_eq!(r5.stats.transfers, r1.stats.transfers);
}

#[test]
fn shipped_fused_example_matches_the_registry_source() {
    // examples/plans/tp_block_fused_w2.sched documents the fused block; it
    // must stay in sync with `plan import --from tp-block --world 2`
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/plans/tp_block_fused_w2.sched");
    let text = std::fs::read_to_string(path).expect("shipped corpus file");
    let parsed = parse_schedule(&text).unwrap();
    assert_eq!(parsed, registry::build("tp-block", 2).unwrap());
}
