//! Plan-interchange corpus tests (ISSUE 2 acceptance):
//!
//! * every registered plan source — all schedule templates AND all
//!   imported baseline plans — round-trips `parse(print(s)) == s`
//!   structurally at worlds 2/4/8, with bit-identical re-printing, and
//!   passes `validate()`;
//! * the shipped `examples/plans/*.sched` corpus parses, validates, and
//!   round-trips (the same checks `plan lint` runs in CI);
//! * malformed inputs fail with `line L, col C:` positions;
//! * a schedule authored purely in the textual DSL executes through both
//!   engines bit-identically.

use std::path::PathBuf;

use syncopate::codegen::compile_comm_only;
use syncopate::exec::{run_with, BufferStore, ExecOptions};
use syncopate::plan_io::{parse_schedule, print_schedule, registry};
use syncopate::runtime::Runtime;
use syncopate::schedule::validate::validate;

#[test]
fn every_source_roundtrips_at_worlds_2_4_8() {
    for src in registry::sources() {
        for world in [2usize, 4, 8] {
            let tag = format!("{} @ world {world}", src.name);
            let s = src.build(world).unwrap_or_else(|e| panic!("{tag}: {e}"));
            validate(&s).unwrap_or_else(|e| panic!("{tag}: {e}"));

            let printed = print_schedule(&s).unwrap_or_else(|e| panic!("{tag}: {e}"));
            let parsed = parse_schedule(&printed).unwrap_or_else(|e| panic!("{tag}: {e}"));
            assert_eq!(parsed, s, "{tag}: parse(print(s)) != s");
            validate(&parsed).unwrap_or_else(|e| panic!("{tag} (reparsed): {e}"));

            let reprinted = print_schedule(&parsed).unwrap();
            assert_eq!(reprinted, printed, "{tag}: print->parse->print not bit-identical");
        }
    }
}

#[test]
fn split_refinements_roundtrip_too() {
    // the autotuner's split knob must not push plans out of the format
    for name in ["ag-ring", "ag-swizzle", "rs-direct", "flux-ag", "tdist-ag"] {
        let s = registry::build(name, 4).unwrap().split_p2p(0, 2).unwrap();
        validate(&s).unwrap();
        let printed = print_schedule(&s).unwrap();
        assert_eq!(parse_schedule(&printed).unwrap(), s, "{name} split");
    }
}

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/plans")
}

#[test]
fn shipped_corpus_parses_validates_and_roundtrips() {
    let dir = corpus_dir();
    let mut seen = 0usize;
    for entry in std::fs::read_dir(&dir).expect("examples/plans must exist") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("sched") {
            continue;
        }
        seen += 1;
        let tag = path.display().to_string();
        let text = std::fs::read_to_string(&path).unwrap();
        let s = parse_schedule(&text).unwrap_or_else(|e| panic!("{tag}: {e}"));
        validate(&s).unwrap_or_else(|e| panic!("{tag}: {e}"));
        let printed = print_schedule(&s).unwrap();
        assert_eq!(parse_schedule(&printed).unwrap(), s, "{tag}");
    }
    assert!(seen >= 3, "shipped corpus went missing ({seen} files in {dir:?})");
}

#[test]
fn malformed_inputs_report_line_and_col() {
    // (input, expected line, expected message fragment)
    let cases = [
        ("plan v2 world 4\n", "line 1", "unsupported plan version"),
        ("plan v1 world 0\n", "line 1", "world must be > 0"),
        ("tensor x f32 4x4\n", "line 1", "header"),
        ("plan v1 world 2\ntensor x f99 4x4\n", "line 2, col 10", "unknown dtype"),
        (
            "plan v1 world 2\ntensor x f32 4x4\nrank 0:\n  zap x[0:1, 0:4] -> x[0:1, 0:4] peer 1\n",
            "line 4, col 3",
            "unknown op",
        ),
        (
            "plan v1 world 2\ntensor x f32 4x4\nrank 0:\n  push y[0:1, 0:4] -> y[0:1, 0:4] peer 1\n",
            "line 4, col 8",
            "unknown tensor",
        ),
        (
            "plan v1 world 2\ntensor x f32 4x4\nrank 0:\n  push x[0:1, 0:4] -> x[0:1, 0:4]\n",
            "line 4",
            "expected `peer`",
        ),
        (
            "plan v1 world 2\ntensor x f32 4x4\nrank 0:\n  push x[1:0, 0:4] -> x[0:1, 0:4] peer 1\n",
            "line 4",
            "inverted range",
        ),
        ("plan v1 world 2\nrank 9:\n", "line 2, col 6", "out of world"),
    ];
    for (input, at, what) in cases {
        let e = parse_schedule(input).unwrap_err().to_string();
        assert!(e.contains(at), "`{input}` -> {e} (wanted position {at})");
        assert!(e.contains(what), "`{input}` -> {e} (wanted `{what}`)");
    }
}

#[test]
fn dsl_only_schedule_executes_bit_identically_in_both_engines() {
    // authored as text, never through the Rust builder API
    let text = std::fs::read_to_string(corpus_dir().join("hetero_fig4e_2x2.sched")).unwrap();
    let sched = parse_schedule(&text).unwrap();
    validate(&sched).unwrap();
    let topo = syncopate::hw::catalog::topology_nodes("h100_multinode", 2, 4).unwrap();
    let real = syncopate::autotune::tune_user_plan(&sched, &topo).unwrap().real;
    let plan = compile_comm_only(&sched, real, &topo).unwrap();
    let rt = Runtime::host_reference();

    let seed_store = || {
        let mut store = BufferStore::new(4);
        store.declare("x", &[8, 16]).unwrap();
        for r in 0..4 {
            let mut xr = vec![0.0f32; 8 * 16];
            for (i, v) in xr[r * 2 * 16..(r * 2 + 2) * 16].iter_mut().enumerate() {
                *v = (r * 1000 + i) as f32 * 0.5;
            }
            store.set(r, "x", &xr).unwrap();
        }
        store
    };

    let seq = seed_store();
    run_with(&plan, &sched.tensors, &seq, &rt, &ExecOptions::sequential()).unwrap();
    let par = seed_store();
    run_with(&plan, &sched.tensors, &par, &rt, &ExecOptions::parallel()).unwrap();
    for r in 0..4 {
        let a = seq.get(r, "x").unwrap();
        let b = par.get(r, "x").unwrap();
        assert_eq!(a, b, "rank {r} diverged between engines");
        // and the gather completed: no zeros remain anywhere but position 0
        assert!(a.iter().skip(1).all(|&v| v != 0.0), "rank {r} missed a shard");
    }
}
