//! Unsafe audit (ISSUE 8 satellite): every `unsafe` site in the crate —
//! block, fn, impl, or extern — must carry a `// SAFETY:` justification
//! directly above it, and the crate root must deny implicit
//! unsafe-op-in-unsafe-fn. Enforced textually so a new unsafe block cannot
//! land without its argument.

use std::path::{Path, PathBuf};

fn src_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in std::fs::read_dir(dir).unwrap() {
        let path = entry.unwrap().path();
        if path.is_dir() {
            rs_files(&path, out);
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
}

fn is_unsafe_code_line(line: &str) -> bool {
    let t = line.trim_start();
    if t.starts_with("//") {
        return false; // the word in prose is not a site
    }
    if t.contains("unsafe_op_in_unsafe_fn") {
        return false; // the lint name in attributes is not a site
    }
    ["unsafe {", "unsafe{", "unsafe fn", "unsafe impl", "unsafe extern"]
        .iter()
        .any(|p| t.contains(p))
}

#[test]
fn every_unsafe_site_has_a_safety_comment() {
    let mut files = Vec::new();
    rs_files(&src_dir(), &mut files);
    files.sort();
    let mut sites = 0usize;
    for path in &files {
        let text = std::fs::read_to_string(path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        for (i, line) in lines.iter().enumerate() {
            if !is_unsafe_code_line(line) {
                continue;
            }
            sites += 1;
            // walk upward through comments, attributes, blanks, and
            // adjacent unsafe lines (one SAFETY comment may cover a
            // Send+Sync impl pair) until the comment or real code
            let mut j = i;
            let mut found = false;
            while j > 0 {
                j -= 1;
                let t = lines[j].trim_start();
                if t.contains("SAFETY:") {
                    found = true;
                    break;
                }
                let skippable = t.starts_with("//")
                    || t.starts_with('#')
                    || t.is_empty()
                    || is_unsafe_code_line(lines[j]);
                if !skippable {
                    break;
                }
            }
            assert!(
                found,
                "{}:{}: unsafe without a `// SAFETY:` comment above it",
                path.display(),
                i + 1
            );
        }
    }
    // the crate currently has exactly 4 sites (2 asm blocks, 1 Send+Sync
    // pair); if this ever reads 0 the matcher broke, not the code
    assert!(sites >= 1, "audit matched no unsafe sites — matcher broke");
}

#[test]
fn crate_denies_implicit_unsafe_in_unsafe_fn() {
    let lib = std::fs::read_to_string(src_dir().join("lib.rs")).unwrap();
    assert!(
        lib.contains("#![deny(unsafe_op_in_unsafe_fn)]"),
        "lib.rs must keep the unsafe_op_in_unsafe_fn deny"
    );
}
