//! Hardware-model integration (ISSUE 4 acceptance):
//!
//! * catalog-wide sweep — every registry exec case builds, validates, and
//!   simulates on every catalog topology at worlds 2/4/8;
//! * both exec engines stay bit-identical on a non-H100 topology, and
//!   real-numerics verification passes off-H100;
//! * the shipped `examples/topos/*.topo` files stay in sync with the
//!   built-in catalog and round-trip (the same checks `topo lint` runs in
//!   CI);
//! * topology fingerprints distinguish every catalog shape and world size.

use std::path::PathBuf;

use syncopate::coordinator::execases::{self, run_and_verify, AgVariant, CaseParams};
use syncopate::hw::{catalog, fingerprint, parse_desc, print_desc};
use syncopate::runtime::Runtime;
use syncopate::schedule::validate::validate;
use syncopate::sim::engine::{simulate, SimParams};

fn topos_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/topos")
}

#[test]
fn every_case_builds_validates_and_simulates_on_every_catalog_topology() {
    for name in catalog::names() {
        for world in [2usize, 4, 8] {
            for spec in execases::CASES {
                let tag = format!("{} on {name} @ world {world}", spec.name);
                let p = CaseParams {
                    world,
                    topo: name.to_string(),
                    ..Default::default()
                };
                let case = spec.build(&p).unwrap_or_else(|e| panic!("{tag}: {e}"));
                validate(&case.sched).unwrap_or_else(|e| panic!("{tag}: {e}"));
                let r = simulate(&case.plan, &case.topo, SimParams::default())
                    .unwrap_or_else(|e| panic!("{tag}: {e}"));
                assert!(r.makespan_us > 0.0, "{tag}: zero makespan");
            }
        }
    }
}

#[test]
fn exec_engines_bit_identical_on_non_h100_topology() {
    // DESIGN.md §6 cross-mode equivalence, off the reference machine: the
    // backend matrix changes timing, never numerics.
    let rt = Runtime::open_default().expect("host-ref fallback cannot fail");
    let a100 = catalog::topology("a100_node", 4).unwrap();
    execases::verify_modes_bit_identical(
        &|| execases::ag_gemm_variant_on(&a100, 2, 42, AgVariant::PullSwizzle),
        &rt,
    )
    .unwrap();
    execases::verify_modes_bit_identical(&|| execases::gemm_ar_on(&a100, 7), &rt).unwrap();
}

#[test]
fn exec_cases_verify_on_every_non_h100_catalog_topology() {
    let rt = Runtime::open_default().expect("host-ref fallback cannot fail");
    for name in ["a100_node", "b200_node", "mixed_multinode"] {
        let p = CaseParams { world: 2, topo: name.to_string(), ..Default::default() };
        let case = execases::build_case("ag-gemm", &p).unwrap();
        let case_name = case.name.clone();
        run_and_verify(case, &rt).unwrap_or_else(|e| panic!("{case_name} on {name}: {e}"));
    }
}

#[test]
fn shipped_topo_files_match_builtin_catalog() {
    let dir = topos_dir();
    for name in catalog::names() {
        let path = dir.join(format!("{name}.topo"));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let parsed = parse_desc(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
        let builtin = catalog::desc(name).unwrap();
        assert_eq!(parsed, builtin, "{name}: shipped .topo drifted from the builtin");
        // lint-grade checks: canonical reprint round-trips bit-stably
        let canon = print_desc(&parsed);
        assert_eq!(parse_desc(&canon).unwrap(), parsed, "{name}: round trip");
        assert_eq!(print_desc(&parse_desc(&canon).unwrap()), canon, "{name}: reprint");
    }
}

#[test]
fn fingerprints_distinguish_catalog_shapes_and_worlds() {
    let mut seen = std::collections::HashMap::new();
    for name in catalog::names() {
        for world in [2usize, 4, 8] {
            let fp = fingerprint(&catalog::topology(name, world).unwrap());
            if let Some(prev) = seen.insert(fp.clone(), format!("{name}@{world}")) {
                panic!("fingerprint collision: {prev} vs {name}@{world} ({fp})");
            }
        }
    }
    // deterministic across instantiations
    assert_eq!(
        fingerprint(&catalog::topology("b200_node", 4).unwrap()),
        fingerprint(&catalog::topology("b200_node", 4).unwrap())
    );
}

#[test]
fn hier_case_splits_single_node_descs_across_nodes() {
    // ag-gemm-hier keeps its historical 2-node H100 shape on the default
    // topo; a single-node description is split across --nodes with its OWN
    // device/links; a multinode description's node structure wins outright.
    let def = execases::build_case("ag-gemm-hier", &CaseParams::default()).unwrap();
    assert_eq!(def.topo.ranks_per_node, 2, "default: 4 ranks over 2 nodes");
    assert_eq!(def.topo.sms_per_device, 132);
    let p = CaseParams { topo: "b200_node".to_string(), ..Default::default() };
    let b200 = execases::build_case("ag-gemm-hier", &p).unwrap();
    assert_eq!(b200.topo.ranks_per_node, 2, "--nodes 2 splits the b200 description");
    assert_eq!(b200.topo.sms_per_device, 148, "the named device params still apply");
    simulate(&b200.plan, &b200.topo, SimParams::default()).unwrap();
    let p = CaseParams { topo: "mixed_multinode".to_string(), nodes: 4, ..Default::default() };
    let mixed = execases::build_case("ag-gemm-hier", &p).unwrap();
    assert_eq!(mixed.topo.ranks_per_node, 2, "multinode desc ignores --nodes");
}

#[test]
fn topo_file_paths_work_end_to_end_as_case_topologies() {
    // a .topo FILE (not a catalog name) drives an exec case: write one
    // out, point CaseParams at the path, run with real numerics
    let d = catalog::desc("a100_node").unwrap();
    let path = std::env::temp_dir().join("syncopate_integration_hw.topo");
    std::fs::write(&path, print_desc(&d)).unwrap();
    let p = CaseParams {
        world: 2,
        topo: path.to_str().unwrap().to_string(),
        ..Default::default()
    };
    let case = execases::build_case("gemm-rs", &p).unwrap();
    assert_eq!(case.topo.sms_per_device, 108, "the file's device params must apply");
    let rt = Runtime::open_default().unwrap();
    run_and_verify(case, &rt).unwrap();
    let _ = std::fs::remove_file(&path);
}
