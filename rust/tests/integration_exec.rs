//! Integration: every distributed operator executed with REAL numerics
//! through the full stack (schedule -> codegen -> exec engine -> kernel
//! runtime), verified against host oracles (DESIGN.md §6). Runs on the AOT
//! artifacts when `make artifacts` has produced them, and on the
//! host-reference runtime backend otherwise — either way the whole
//! execution stack is exercised on a bare checkout.
//!
//! This file drives the sequential reference engine; the parallel engine
//! (and its bit-identity to this one) is covered by integration_parallel.rs.

use syncopate::coordinator::execases::{self, run_and_verify};
use syncopate::runtime::Runtime;

fn rt() -> Runtime {
    Runtime::open_default().expect("open_default falls back to host-ref; cannot fail")
}

#[test]
fn ag_gemm_all_worlds_and_splits() {
    let rt = rt();
    for world in [2usize, 4, 8] {
        for split in [1usize, 2, 4] {
            let case = execases::ag_gemm(world, split, 7 + world as u64).unwrap();
            let name = case.name.clone();
            let stats = run_and_verify(case, &rt)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            // swizzle AG: (w-1) pulls per rank, times split
            assert_eq!(stats.transfers, world * (world - 1) * split, "{name}");
        }
    }
}

#[test]
fn gemm_rs_all_worlds() {
    let rt = rt();
    for world in [2usize, 4, 8] {
        let case = execases::gemm_rs(world, 100 + world as u64).unwrap();
        let name = case.name.clone();
        let stats = run_and_verify(case, &rt).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(stats.transfers, world * (world - 1));
    }
}

#[test]
fn gemm_ar_all_worlds() {
    let rt = rt();
    for world in [2usize, 4, 8] {
        let case = execases::gemm_ar(world, 200 + world as u64).unwrap();
        let name = case.name.clone();
        let stats = run_and_verify(case, &rt).unwrap_or_else(|e| panic!("{name}: {e}"));
        // partition AR: (w-1) reduce pushes + (w-1) broadcasts per rank
        assert_eq!(stats.transfers, 2 * world * (world - 1));
    }
}

#[test]
fn a2a_gemm_all_worlds() {
    let rt = rt();
    for world in [2usize, 4] {
        let case = execases::a2a_gemm(world, 300 + world as u64).unwrap();
        let name = case.name.clone();
        run_and_verify(case, &rt).unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn ring_attention_worlds_and_splits() {
    let rt = rt();
    for world in [2usize, 4] {
        for split in [1usize, 2] {
            let case = execases::ring_attention(world, split, 400 + world as u64).unwrap();
            let name = case.name.clone();
            let stats = run_and_verify(case, &rt).unwrap_or_else(|e| panic!("{name}: {e}"));
            // k and v rings, (w-1) hops each, split sub-chunks
            assert_eq!(stats.transfers, world * 2 * (world - 1) * split, "{name}");
        }
    }
}

#[test]
fn push_pull_and_ring_variants_all_verify() {
    // Fig. 4(a)/(b)/(c): the same logical AllGather realized as pull
    // swizzle, push ring (with forwarding dep chains: ranks re-send data
    // they received), and push direct — identical numerics everywhere.
    use syncopate::coordinator::execases::AgVariant;
    let rt = rt();
    for variant in [AgVariant::PullSwizzle, AgVariant::PushRing, AgVariant::PushDirect] {
        for world in [2usize, 4] {
            let case = execases::ag_gemm_variant(world, 1, 808, variant).unwrap();
            let name = case.name.clone();
            run_and_verify(case, &rt).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }
    // ring with split: sub-chunk forwarding deps
    let case = execases::ag_gemm_variant(4, 2, 808, AgVariant::PushRing).unwrap();
    run_and_verify(case, &rt).unwrap();
}

#[test]
fn hierarchical_ag_gemm_two_level_mesh() {
    // the Fig. 4(e) heterogeneous swizzle with REAL numerics: intra-node
    // ring + cross-node mirror exchange + pipelined redistribution
    let rt = rt();
    for (nodes, rpn) in [(2usize, 2usize), (2, 4)] {
        let case = execases::ag_gemm_hierarchical(nodes, rpn, 77).unwrap();
        let name = case.name.clone();
        run_and_verify(case, &rt).unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn attn_sp_all_worlds() {
    let rt = rt();
    for world in [2usize, 4] {
        let case = execases::attn_sp(world, 500 + world as u64).unwrap();
        let name = case.name.clone();
        let stats = run_and_verify(case, &rt).unwrap_or_else(|e| panic!("{name}: {e}"));
        // direct pull swizzle: (w-1) pulls per rank per tensor, no deps
        assert_eq!(stats.transfers, world * 2 * (world - 1));
    }
}

#[test]
fn numerics_invariant_across_splits() {
    // DESIGN.md §6: any valid split factor produces identical results.
    // run_and_verify already compares against the oracle; both splits
    // passing with the same seed proves split-invariance transitively.
    let rt = rt();
    for split in [1usize, 2, 4] {
        let case = execases::ag_gemm(4, split, 999).unwrap();
        run_and_verify(case, &rt).unwrap();
    }
    for split in [1usize, 2] {
        let case = execases::ring_attention(4, split, 999).unwrap();
        run_and_verify(case, &rt).unwrap();
    }
}

#[test]
fn numerics_stable_across_seeds() {
    let rt = rt();
    for seed in [1u64, 17, 4242, 1 << 40] {
        run_and_verify(execases::gemm_ar(4, seed).unwrap(), &rt).unwrap();
    }
}

#[test]
fn exec_stats_account_bytes() {
    let rt = rt();
    let case = execases::ag_gemm(4, 1, 5).unwrap();
    let stats = run_and_verify(case, &rt).unwrap();
    // each pull moves a 32x128 f32 shard; 4 ranks x 3 pulls
    assert_eq!(stats.bytes_moved, 4 * 3 * 32 * 128 * 4);
    assert_eq!(stats.compute_calls, 4 * 4); // 4 tiles per rank (bm=32)
}
