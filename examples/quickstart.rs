//! Quickstart: compile, simulate, tune and *really execute* one distributed
//! operator through the public API.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks the full Syncopate pipeline for AllGather-GEMM:
//!  1. paper-scale: schedule template -> chunk split -> swizzle -> plan ->
//!     calibrated simulation, compared against a kernel-level baseline;
//!  2. autotune the chunk knobs;
//!  3. validation-scale: the same pipeline with real buffers and the AOT
//!     Pallas kernels via PJRT, verified against a host oracle.

use syncopate::autotune::{self, Budget};
use syncopate::baselines::{self, Baseline};
use syncopate::coordinator::execases;
use syncopate::coordinator::operators::compile_operator;
use syncopate::coordinator::TuneConfig;
use syncopate::runtime::Runtime;
use syncopate::sim::engine::simulate;
use syncopate::util::fmt_us;
use syncopate::workload::{OpKind, OperatorInstance, LLAMA3_8B};

fn main() -> syncopate::Result<()> {
    let world = 8;
    let topo = syncopate::hw::catalog::topology("h100_node", world)?;
    let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 8192, world);
    println!("== Syncopate quickstart: {} ==\n", op.label());

    // 1. one hand-picked configuration
    let cfg = TuneConfig::default();
    let (plan, params) = compile_operator(&op, &cfg, &topo)?;
    let r = simulate(&plan, &topo, params)?;
    println!("default config     : {}", cfg.label());
    println!(
        "  makespan {:>10}   {:.0} TFLOPS   exposed comm {}",
        fmt_us(r.makespan_us),
        r.tflops(),
        fmt_us(r.exposed_wait_us)
    );

    // 2. the kernel-level baseline on the same operator
    let (bplan, bparams) = baselines::plan(Baseline::KernelLevel, &op, &topo)?;
    let b = simulate(&bplan, &topo, bparams)?;
    println!("kernel-level base  :");
    println!("  makespan {:>10}   {:.0} TFLOPS", fmt_us(b.makespan_us), b.tflops());

    // 3. autotune the chunk knobs
    let tuned = autotune::tune(&op, &topo, Budget::Quick)?;
    println!("autotuned          : {}", tuned.cfg.label());
    println!(
        "  makespan {:>10}   {:.0} TFLOPS   ({} configs evaluated, {} pruned)",
        fmt_us(tuned.makespan_us),
        tuned.tflops,
        tuned.evaluated,
        tuned.pruned
    );
    println!("  speedup vs kernel-level: {:.2}x\n", b.makespan_us / tuned.makespan_us);

    // 4. real numerics at validation scale (same pipeline, real kernels)
    let rt = Runtime::open_default()?;
    let case = execases::ag_gemm(4, 2, 42)?;
    let name = case.name.clone();
    let stats = execases::run_and_verify(case, &rt)?;
    println!(
        "real execution     : {name} VERIFIED against host oracle \
         ({} chunk transfers, {} Pallas-kernel calls)",
        stats.transfers, stats.compute_calls
    );
    Ok(())
}
