//! End-to-end driver (DESIGN.md §6): a full transformer block executed
//! across a simulated multi-GPU mesh with REAL numerics, plus the
//! paper-scale performance comparison for the same layer.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_transformer
//! ```
//!
//! Stage 1 (validation scale, real compute): one fused plan per rank that
//!   * ring-rotates K/V shards and folds each arrival with the online-
//!     softmax Pallas kernel (RingAttention),
//!   * finalizes the attention output,
//!   * computes a tensor-parallel FFN shard with the fused
//!     gelu(x@W1+b1)@W2 artifact, and
//!   * AllReduces the partial FFN outputs with the partition schedule
//!     (Fig. 4d) — all inside ONE executable plan with chunk-level overlap.
//!   Every rank's outputs are verified against host oracles.
//!
//! Stage 2 (paper scale): the same layer (RingAttention + GEMM-AR FFN,
//!   Llama-3-8B dimensions, 8 GPUs) through the autotuner vs the
//!   kernel-level and sequential baselines on the calibrated model. These
//!   numbers are the ones recorded in EXPERIMENTS.md §E2E.

use std::collections::HashMap;

use syncopate::autotune::{self, Budget};
use syncopate::baselines::{self, Baseline};
use syncopate::chunk::{DType, TensorTable};
use syncopate::codegen::{compile, CallSpec, RankComputeInput, Realization};
use syncopate::coordinator::execases::{run_and_verify, Check, ExecCase};
use syncopate::depgraph::{plan_rank_sync, ChunkTileMap};
use syncopate::exec::verify::{host_attention, host_gelu, host_gemm, host_sum};
use syncopate::exec::BufferStore;
use syncopate::kernel::grid::{Axis, TileGrid};
use syncopate::kernel::scheduler::{IntraOrder, TileScheduler};
use syncopate::runtime::Runtime;
use syncopate::schedule::{templates, OpRef};
use syncopate::sim::engine::simulate;
use syncopate::util::fmt_us;
use syncopate::workload::{OpKind, OperatorInstance, LLAMA3_8B};

const SQ: usize = 64; // per-rank query shard
const D: usize = 64; // head dim
const FM: usize = 64; // FFN rows
const FD: usize = 128; // FFN hidden
const FF: usize = 64; // per-rank FFN intermediate shard

/// Build the fused transformer-block exec case for `world` ranks.
fn transformer_block_case(world: usize, seed: u64) -> syncopate::Result<ExecCase> {
    let topo = syncopate::hw::catalog::topology("h100_node", world)?;
    let s_total = world * SQ;

    // --- tensors ---------------------------------------------------------
    let mut table = TensorTable::new();
    let k = table.declare("k", &[s_total, D], DType::F32)?;
    let v = table.declare("v", &[s_total, D], DType::F32)?;
    for (name, shape) in [
        ("q", vec![SQ, D]),
        ("acc", vec![SQ, D]),
        ("m", vec![SQ]),
        ("l", vec![SQ]),
        ("o", vec![SQ, D]),
        ("x", vec![FM, FD]),
        ("w1", vec![FD, FF]),
        ("b1", vec![FF]),
        ("w2", vec![FF, FD]),
    ] {
        table.declare(name, &shape, DType::F32)?;
    }
    let y = table.declare("y", &[FM, FD], DType::F32)?;

    // --- communication schedule: KV rings + partition-AllReduce(y) -------
    let mut sched = templates::all_gather_ring(&table, k, 0, world)?;
    sched.append(&templates::all_gather_ring(&table, v, 0, world)?)?;
    sched.append(&templates::all_reduce_partition(&table, y, 0, world)?)?;

    // --- grid: w attention-step tiles + 1 FFN tile ------------------------
    let grid = TileGrid::new(vec![Axis::new("T", (world + 1) * SQ, SQ)?])?;
    let ffn_tile = world; // last tile id

    // --- deterministic data + oracles -------------------------------------
    let mut rng = syncopate::util::Rng::new(seed);
    let qs: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(SQ * D)).collect();
    let k_glob = rng.vec_f32(s_total * D);
    let v_glob = rng.vec_f32(s_total * D);
    let x_glob = rng.vec_f32(FM * FD);
    let w1s: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(FD * FF)).collect();
    let b1s: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(FF)).collect();
    let w2s: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(FF * FD)).collect();

    let mut store = BufferStore::new(world);
    for (id, decl) in table.iter() {
        let _ = id;
        store.declare(&decl.name, &decl.shape)?;
    }
    for r in 0..world {
        let mut kr = vec![0.0f32; s_total * D];
        let mut vr = vec![0.0f32; s_total * D];
        let a = r * SQ * D;
        kr[a..a + SQ * D].copy_from_slice(&k_glob[a..a + SQ * D]);
        vr[a..a + SQ * D].copy_from_slice(&v_glob[a..a + SQ * D]);
        store.set(r, "k", &kr)?;
        store.set(r, "v", &vr)?;
        store.set(r, "q", &qs[r])?;
        store.set(r, "m", &[-1e30f32; SQ])?;
        store.set(r, "x", &x_glob)?;
        store.set(r, "w1", &w1s[r])?;
        store.set(r, "b1", &b1s[r])?;
        store.set(r, "w2", &w2s[r])?;
    }

    // --- per-rank compute inputs ------------------------------------------
    let mut inputs = Vec::new();
    for rank in 0..world {
        let mut map = ChunkTileMap::default();
        for (r, ops) in sched.per_rank.iter().enumerate() {
            for (index, op) in ops.iter().enumerate() {
                let opref = OpRef { rank: r, index };
                let tensor = &sched.tensors.get(op.produced_chunk().tensor)?.name;
                if (tensor == "k" || tensor == "v") && op.dst_rank(r) == rank {
                    // KV arrival feeds the attention tile of those rows
                    let reg = &op.produced_chunk().region;
                    let tiles = grid.tiles_intersecting(&[Some((
                        reg.offset[0],
                        reg.offset[0] + reg.sizes[0],
                    ))])?;
                    map.consumers.entry(opref).or_default().extend(tiles);
                }
                if tensor == "y" && op.src_rank(r) == rank {
                    // every outgoing y chunk is produced by the FFN tile
                    map.producers.entry(opref).or_default().push(ffn_tile);
                }
            }
        }
        // chunk-major order: FFN tile is "local" (no incoming chunk) and
        // runs first, overlapping with the first KV hop in flight
        let groups = map.consumer_groups(rank);
        let arrival: Vec<usize> = (0..groups.len()).collect();
        let order =
            TileScheduler::chunk_major(&grid, &groups, &arrival, IntraOrder::RowMajor)?;
        let sync = plan_rank_sync(rank, &sched, &order, &map)?;

        let mut tile_calls: HashMap<usize, Vec<CallSpec>> = HashMap::new();
        for t in 0..world {
            let (k0, k1) = grid.axis_span(0, t);
            tile_calls.insert(
                t,
                vec![CallSpec::AttnStep {
                    artifact: format!("attn_step_q{SQ}d{D}k{SQ}"),
                    q: "q".into(),
                    k: "k".into(),
                    v: "v".into(),
                    kv_rows: (k0, k1),
                    acc: "acc".into(),
                    m: "m".into(),
                    l: "l".into(),
                }],
            );
        }
        tile_calls.insert(
            ffn_tile,
            vec![CallSpec::FfnShard {
                artifact: format!("ffn_shard_{FM}x{FD}x{FF}"),
                x: "x".into(),
                w1: "w1".into(),
                b1: "b1".into(),
                w2: "w2".into(),
                out: "y".into(),
                accumulate: true,
            }],
        );
        // finalize after the LAST attention step in visit order
        let last_attn = *order.order.iter().rev().find(|&&t| t < world).unwrap();
        tile_calls.get_mut(&last_attn).unwrap().push(CallSpec::AttnFinalize {
            artifact: format!("attn_finalize_q{SQ}d{D}"),
            acc: "acc".into(),
            l: "l".into(),
            out: "o".into(),
        });

        let mut tile_flops = vec![4.0 * SQ as f64 * SQ as f64 * D as f64; world + 1];
        tile_flops[ffn_tile] = 4.0 * FM as f64 * FD as f64 * FF as f64;
        inputs.push(RankComputeInput { grid: grid.clone(), order, sync, tile_flops, tile_calls });
    }
    let plan = compile(
        &sched,
        &inputs,
        Realization::new(syncopate::backend::BackendKind::LdStSpecialized, 16),
        &topo,
    )?;
    let _ = v;

    // --- oracles -----------------------------------------------------------
    let scale = 1.0 / (D as f32).sqrt();
    let partials: Vec<Vec<f32>> = (0..world)
        .map(|r| {
            let mut h = host_gemm(&x_glob, &w1s[r], FM, FD, FF);
            for (i, hv) in h.iter_mut().enumerate() {
                *hv += b1s[r][i % FF];
            }
            host_gelu(&mut h);
            host_gemm(&h, &w2s[r], FM, FF, FD)
        })
        .collect();
    let prefs: Vec<&[f32]> = partials.iter().map(|p| p.as_slice()).collect();
    let y_full = host_sum(&prefs);

    let mut checks = Vec::new();
    for r in 0..world {
        checks.push(Check {
            rank: r,
            tensor: "o".into(),
            expected: host_attention(&qs[r], &k_glob, &v_glob, SQ, s_total, D, scale),
            what: format!("ring attention @rank{r}"),
        });
        checks.push(Check {
            rank: r,
            tensor: "y".into(),
            expected: y_full.clone(),
            what: format!("tensor-parallel FFN AllReduce @rank{r}"),
        });
    }
    Ok(ExecCase {
        name: format!("transformer-block-w{world}"),
        sched,
        plan,
        store,
        checks,
        topo,
    })
}

fn main() -> syncopate::Result<()> {
    println!("== E2E: transformer block (RingAttention + TP-FFN + AllReduce) ==\n");

    // Stage 1: real numerics across 2, 4, 8 simulated ranks
    let rt = Runtime::open_default()?;
    for world in [2usize, 4, 8] {
        let case = transformer_block_case(world, 1234 + world as u64)?;
        let name = case.name.clone();
        let transfers = case.plan.total_transfers();
        let stats = run_and_verify(case, &rt)?;
        println!(
            "{name}: VERIFIED  ({transfers} chunk transfers, {} kernel calls, {} moved)",
            stats.compute_calls,
            syncopate::util::fmt_bytes(stats.bytes_moved as u64),
        );
    }

    // Stage 2: paper-scale layer performance (Llama-3-8B, 8 GPUs)
    println!("\n-- paper-scale layer (llama3-8b, seq 16k, 8 GPU) --");
    let world = 8;
    let topo = syncopate::hw::catalog::topology("h100_node", world)?;
    let attn = OperatorInstance::attention(OpKind::RingAttn, &LLAMA3_8B, 16384, world);
    let ffn = OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_8B, 16384, world);

    let mut layer_ours = 0.0;
    let mut layer_kl = 0.0;
    let mut layer_seq = 0.0;
    for (label, op) in [("ring-attention", attn), ("ffn gemm-ar", ffn)] {
        let tuned = autotune::tune(&op, &topo, Budget::Quick)?;
        let (kp, kpar) = baselines::plan(Baseline::KernelLevel, &op, &topo)?;
        let kl = simulate(&kp, &topo, kpar)?.makespan_us;
        let (sp, spar) = baselines::plan(Baseline::TritonNccl, &op, &topo)?;
        let seq = simulate(&sp, &topo, spar)?.makespan_us;
        println!(
            "  {label:15} syncopate {:>10} ({})   kernel-level {:>10}   sequential {:>10}",
            fmt_us(tuned.makespan_us),
            tuned.cfg.label(),
            fmt_us(kl),
            fmt_us(seq)
        );
        layer_ours += tuned.makespan_us;
        layer_kl += kl;
        layer_seq += seq;
    }
    println!(
        "  layer total     syncopate {:>10}   kernel-level {:>10} ({:.2}x)   sequential {:>10} ({:.2}x)",
        fmt_us(layer_ours),
        fmt_us(layer_kl),
        layer_kl / layer_ours,
        fmt_us(layer_seq),
        layer_seq / layer_ours
    );
    println!("\n(record these in EXPERIMENTS.md §E2E)");
    Ok(())
}
