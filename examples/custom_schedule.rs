//! Authoring a custom communication schedule with the chunk API
//! (the paper's Listing-2 workflow) and comparing it against the built-in
//! templates on the calibrated model.
//!
//! ```bash
//! cargo run --release --example custom_schedule
//! ```
//!
//! We hand-write a "neighbor-first" AllGather: each rank first pulls from
//! its immediate ring neighbors (cheapest to overlap early), then from
//! progressively farther peers — a plausible schedule an expert might try —
//! validate it, lower it under several backends, and let the tile-scheduler
//! swizzle align compute with it. Then we show what the autotuner finds.

use syncopate::autotune::{self, Budget};
use syncopate::chunk::{Chunk, DType, TensorTable};
use syncopate::codegen::{compile, RankComputeInput, Realization};
use syncopate::coordinator::TuneConfig;
use syncopate::depgraph::{plan_rank_sync, ChunkTileMap};
use syncopate::backend::BackendKind;
use syncopate::kernel::grid::TileGrid;
use syncopate::kernel::scheduler::{IntraOrder, TileScheduler};
use syncopate::schedule::templates::shard_region;
use syncopate::schedule::validate::validate;
use syncopate::schedule::{CommOp, CommSchedule, OpRef, TransferKind};
use syncopate::sim::engine::{simulate, SimParams};
use syncopate::sim::waves;
use syncopate::topo::Topology;
use syncopate::util::fmt_us;
use syncopate::workload::{OpKind, OperatorInstance, LLAMA3_70B};

/// Hand-written pull schedule: nearest ring neighbors first.
fn neighbor_first_all_gather(
    table: &TensorTable,
    tensor: syncopate::chunk::TensorId,
    world: usize,
) -> syncopate::Result<CommSchedule> {
    let shape = table.get(tensor)?.shape.clone();
    let mut sched = CommSchedule::new(world, table.clone());
    for r in 0..world {
        // distance order: 1, -1, 2, -2, ...
        let mut peers = Vec::new();
        for d in 1..=world / 2 {
            peers.push((r + d) % world);
            if d != world - d {
                peers.push((r + world - d) % world);
            }
        }
        for peer in peers {
            let c = Chunk::new(tensor, shard_region(&shape, 0, world, peer)?);
            sched.add_op(
                r,
                CommOp::P2p {
                    kind: TransferKind::Pull,
                    peer,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps: vec![],
                },
            )?;
        }
    }
    Ok(sched)
}

fn main() -> syncopate::Result<()> {
    let world = 8;
    let topo = Topology::h100_node(world)?;
    let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, 8192, world);
    println!("== custom chunk schedule: neighbor-first AllGather ({}) ==\n", op.label());

    // 1. author + validate the schedule
    let mut table = TensorTable::new();
    let x = table.declare("x", &[op.m, op.k], op.dtype)?;
    let sched = neighbor_first_all_gather(&table, x, world)?;
    validate(&sched)?;
    println!(
        "schedule: {} ops, {} moved over links",
        sched.num_ops(),
        syncopate::util::fmt_bytes(sched.total_link_bytes()? as u64)
    );

    // 2. split-factor refinement through the same API the autotuner uses
    let split = 2;
    let sched = sched.split_p2p(0, split)?;
    println!("after split_p2p(axis 0, {split}): {} ops", sched.num_ops());
    // signal numbering is rank-major and dense: each rank owns a
    // contiguous id block of the executors' shared signal board
    for (r, (lo, hi)) in syncopate::codegen::signal_ranges(&sched).iter().enumerate() {
        println!("  rank {r} owns signals [{lo}, {hi})");
    }

    // 3. align compute: chunk-major swizzle + minimal sync + codegen
    let cfg = TuneConfig::default();
    let grid = TileGrid::gemm(op.m, op.n, cfg.block_m, cfg.block_n)?;
    let mut inputs = Vec::new();
    for rank in 0..world {
        let mut map = ChunkTileMap::default();
        for (r, ops) in sched.per_rank.iter().enumerate() {
            for (index, o) in ops.iter().enumerate() {
                if o.dst_rank(r) != rank {
                    continue;
                }
                let reg = &o.produced_chunk().region;
                let tiles = grid.tiles_intersecting(&[
                    Some((reg.offset[0], reg.offset[0] + reg.sizes[0])),
                    None,
                ])?;
                map.consumers.entry(OpRef { rank: r, index }).or_default().extend(tiles);
            }
        }
        let groups = map.consumer_groups(rank);
        let arrival: Vec<usize> = (0..groups.len()).collect();
        let order = TileScheduler::chunk_major(&grid, &groups, &arrival, IntraOrder::Snake)?;
        let sync = plan_rank_sync(rank, &sched, &order, &map)?;
        println!(
            "  rank {rank}: {} waits, first wait after {} tiles (pipeline fill)",
            sync.num_waits(),
            syncopate::depgraph::tiles_before_first_wait(&sync, grid.num_tiles())
        );
        let tile_flops = op.flops() / world as f64 / grid.num_tiles() as f64;
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops: vec![tile_flops; grid.num_tiles()],
            tile_calls: Default::default(),
        });
        if rank == 0 {
            continue; // only print rank 0's stats verbosely below
        }
    }

    // 4. realize under each feasible backend
    println!("\nbackend realizations of the SAME logical schedule:");
    for backend in BackendKind::TUNABLE {
        let sms = if syncopate::backend::curve(backend).sms_for_peak == 0 { 0 } else { 16 };
        let real = Realization::new(backend, sms);
        match compile(&sched, &inputs, real, &topo) {
            Ok(plan) => {
                let params = SimParams {
                    mxu_eff: waves::mxu_efficiency(cfg.block_m, cfg.block_n, cfg.block_k),
                };
                let r = simulate(&plan, &topo, params)?;
                println!(
                    "  {:18} {:>10}  {:.0} TFLOPS  exposed {:>9}",
                    backend.name(),
                    fmt_us(r.makespan_us),
                    r.tflops(),
                    fmt_us(r.exposed_wait_us)
                );
            }
            Err(e) => println!("  {:18} infeasible: {e}", backend.name()),
        }
    }

    // 5. what the autotuner would pick instead
    let tuned = autotune::tune(&op, &topo, Budget::Quick)?;
    println!(
        "\nautotuner's pick over the template space: {} -> {} ({:.0} TFLOPS)",
        tuned.cfg.label(),
        fmt_us(tuned.makespan_us),
        tuned.tflops
    );
    Ok(())
}
