//! Authoring a heterogeneous (Fig. 4e-style) chunk schedule **in the
//! `.sched` DSL**, validating it, and running it through BOTH execution
//! engines with real numerics — the full user-plan workflow without
//! writing a line of schedule-construction Rust.
//!
//! ```bash
//! cargo run --release --example custom_schedule
//! ```
//!
//! The plan below is a hand-written two-level AllGather over 4 ranks in 2
//! nodes: each rank (a) forwards its shard around its local ring, (b)
//! pushes its shard to its mirror rank in the other node, and (c) forwards
//! the mirror's shard locally once it lands — intra-node and cross-node
//! traffic pipelined at per-shard granularity. It is byte-for-byte the
//! plan `schedule::templates::all_gather_hierarchical` generates, which
//! this example *proves* by comparing the parsed schedule against the
//! template — schedules really are an interchange format, not an API.

use syncopate::autotune;
use syncopate::codegen::compile_comm_only;
use syncopate::exec::{run_with, BufferStore, ExecOptions};
use syncopate::plan_io::{content_hash, parse_schedule, print_schedule};
use syncopate::runtime::Runtime;
use syncopate::schedule::templates::all_gather_hierarchical;
use syncopate::schedule::validate::validate;
use syncopate::util::{fmt_us, Rng};

/// Fig. 4e for 4 ranks in 2 nodes, written by hand in the schedule DSL.
/// Tensor `x` is 8x16 f32; rank r owns shard r = rows [2r, 2r+2).
const HETERO_FIG4E: &str = "\
# two-level AllGather: local ring + mirror exchange + pipelined forward
plan v1 world 4
tensor x f32 8x16

rank 0:
  push x[0:2, 0:16] -> x[0:2, 0:16] peer 1            # A: local ring
  push x[0:2, 0:16] -> x[0:2, 0:16] peer 2            # B: cross-node mirror
  push x[4:6, 0:16] -> x[4:6, 0:16] peer 1 deps (2,1) # C: forward mirror's shard
rank 1:
  push x[2:4, 0:16] -> x[2:4, 0:16] peer 0
  push x[2:4, 0:16] -> x[2:4, 0:16] peer 3
  push x[6:8, 0:16] -> x[6:8, 0:16] peer 0 deps (3,1)
rank 2:
  push x[4:6, 0:16] -> x[4:6, 0:16] peer 3
  push x[4:6, 0:16] -> x[4:6, 0:16] peer 0
  push x[0:2, 0:16] -> x[0:2, 0:16] peer 3 deps (0,1)
rank 3:
  push x[6:8, 0:16] -> x[6:8, 0:16] peer 2
  push x[6:8, 0:16] -> x[6:8, 0:16] peer 1
  push x[2:4, 0:16] -> x[2:4, 0:16] peer 2 deps (1,1)
";

const ROWS: usize = 8;
const COLS: usize = 16;
const WORLD: usize = 4;
const SHARD: usize = ROWS / WORLD;

fn main() -> syncopate::Result<()> {
    println!("== user-authored heterogeneous schedule (Fig. 4e, 2 nodes x 2 ranks) ==\n");

    // 1. parse + validate the textual plan
    let sched = parse_schedule(HETERO_FIG4E)?;
    validate(&sched)?;
    let canonical = print_schedule(&sched)?;
    println!(
        "parsed: world {}, {} ops, {} over links, hash {}",
        sched.world,
        sched.num_ops(),
        syncopate::util::fmt_bytes(sched.total_link_bytes()? as u64),
        content_hash(&canonical)
    );

    // 2. round-trip guarantee: parse(print(s)) == s, bit-stable text
    assert_eq!(parse_schedule(&canonical)?, sched, "round-trip must be exact");
    assert_eq!(print_schedule(&parse_schedule(&canonical)?)?, canonical);

    // 3. the hand-written text IS the library template, structurally —
    //    schedules are an interchange artifact, not Rust-only state
    let topo2x2 = syncopate::hw::catalog::topology_nodes("h100_multinode", 2, 4)?;
    let tmpl = all_gather_hierarchical(
        &sched.tensors,
        sched.tensors.lookup("x").expect("declared"),
        0,
        &topo2x2,
    )?;
    assert_eq!(sched, tmpl, "hand-authored DSL == all_gather_hierarchical");
    println!("matches schedule::templates::all_gather_hierarchical exactly\n");

    // 4. restricted autotune: backend + comm SMs only, split fixed by plan
    let tuned = autotune::tune_user_plan(&sched, &topo2x2)?;
    println!(
        "restricted autotune: best backend {:?}/sm{} -> {} simulated \
         ({} evaluated, {} pruned)",
        tuned.real.backend,
        tuned.real.comm_sms,
        fmt_us(tuned.makespan_us),
        tuned.evaluated,
        tuned.pruned
    );

    // 5. execute under BOTH engines with real numerics and compare bits
    let plan = compile_comm_only(&sched, tuned.real, &topo2x2)?;
    let rt = Runtime::host_reference();
    let x_global = Rng::new(7).vec_f32(ROWS * COLS);
    let mk_store = || -> syncopate::Result<BufferStore> {
        let mut store = BufferStore::new(WORLD);
        store.declare("x", &[ROWS, COLS])?;
        for r in 0..WORLD {
            // only rank r's shard is valid initially
            let mut xr = vec![0.0f32; ROWS * COLS];
            let a = r * SHARD * COLS;
            xr[a..a + SHARD * COLS].copy_from_slice(&x_global[a..a + SHARD * COLS]);
            store.set(r, "x", &xr)?;
        }
        Ok(store)
    };

    let mut final_states: Vec<Vec<Vec<f32>>> = Vec::new();
    for opts in [ExecOptions::sequential(), ExecOptions::parallel()] {
        let store = mk_store()?;
        let stats = run_with(&plan, &sched.tensors, &store, &rt, &opts)?;
        println!(
            "exec [{:?}]: {} transfers, {} moved, {} waits",
            opts.mode,
            stats.transfers,
            syncopate::util::fmt_bytes(stats.bytes_moved as u64),
            stats.waits_hit
        );
        let state: Vec<Vec<f32>> =
            (0..WORLD).map(|r| store.get(r, "x")).collect::<syncopate::Result<_>>()?;
        final_states.push(state);
    }
    for r in 0..WORLD {
        assert_eq!(
            final_states[0][r], final_states[1][r],
            "engines must agree bitwise on rank {r}"
        );
        assert_eq!(final_states[0][r], x_global, "rank {r} must gather the full tensor");
    }
    println!("both engines gathered the full tensor bit-identically on every rank\n");

    // 6. the split-factor knob applies to user plans like any template:
    //    1-row sub-chunks, deps re-pipelined, same final state
    let split = sched.split_p2p(0, 2)?;
    validate(&split)?;
    let split_plan = compile_comm_only(&split, tuned.real, &topo2x2)?;
    let store = mk_store()?;
    let stats = run_with(&split_plan, &split.tensors, &store, &rt, &ExecOptions::parallel())?;
    for r in 0..WORLD {
        assert_eq!(store.get(r, "x")?, x_global, "split plan diverged on rank {r}");
    }
    println!(
        "split_p2p(axis 0, 2): {} ops ({} transfers executed), still exact",
        split.num_ops(),
        stats.transfers
    );
    Ok(())
}
