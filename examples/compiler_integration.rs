//! Integrating higher-level distributed compilers (the Fig. 10 workflow):
//! partition-based IRs (Domino/Alpa-style) and loop-based IRs
//! (Mercury-style) lowered into chunk schedules via the three collective
//! paths, then realized as fine-grained overlapped plans.
//!
//! ```bash
//! cargo run --release --example compiler_integration
//! ```

use syncopate::autotune::{self, Budget};
use syncopate::backend::BackendKind;
use syncopate::baselines::{self, Baseline};
use syncopate::codegen::Realization;
use syncopate::lowering::collective::LowerPath;
use syncopate::lowering::{loops, partition};
use syncopate::reports::comm_only_latency_us;
use syncopate::schedule::validate::validate;
use syncopate::sim::engine::simulate;
use syncopate::util::fmt_us;
use syncopate::workload::{OpKind, OperatorInstance, LLAMA3_70B};

fn main() -> syncopate::Result<()> {
    let world = 8;
    let topo = syncopate::hw::catalog::topology("h100_node", world)?;
    println!("== compiler integration: partition + loop IRs -> chunk schedules ==\n");

    // --- partition-based IRs (Domino / Alpa) -----------------------------
    let irs = [
        ("domino-ffn (AG + AR)", partition::presets::domino_ffn(world, 8192, 8192, 8192)),
        ("alpa-ffn   (AG + RS)", partition::presets::alpa_ffn(world, 8192, 8192, 8192)),
    ];
    for (name, ir) in &irs {
        println!("{name}:");
        for t in &ir.tensors {
            let coll = partition::implied_collective(t.src, t.dst)?;
            println!("  tensor `{}` {:?} -> {:?}  =>  {:?}", t.name, t.src, t.dst, coll);
        }
        for path in [LowerPath::Direct, LowerPath::Template, LowerPath::Synth] {
            let sched = partition::lower_partition_ir(ir, &topo, path)?;
            validate(&sched)?;
            let us = comm_only_latency_us(
                &sched,
                Realization::new(BackendKind::LdStSpecialized, 32),
                &topo,
            )?;
            println!(
                "  path {:8} -> {:4} chunk ops, comm-only {:>10}",
                path.name(),
                sched.num_ops(),
                fmt_us(us)
            );
        }
        println!();
    }

    // --- loop-based IR (Mercury ring attention) ---------------------------
    let ir = loops::presets::mercury_ring_attention(world, 16384, LLAMA3_70B.heads * 128);
    let intents = loops::parse_comm_intents(&ir);
    println!("mercury-ring: {} rotate intents parsed from the loop nest", intents.len());
    let sched = loops::lower_loop_ir(&ir, &topo)?;
    validate(&sched)?;
    println!(
        "  lowered to {} chunk ops ({} over links)\n",
        sched.num_ops(),
        syncopate::util::fmt_bytes(sched.total_link_bytes()? as u64)
    );

    // --- end-to-end effect: native kernel-level vs +syncopate -------------
    println!("keeping each system's parallelization fixed, regenerating the kernels:");
    let cases = [
        ("domino ", OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_70B, 8192, world)),
        ("alpa   ", OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_70B, 8192, world)),
        ("mercury", OperatorInstance::attention(OpKind::RingAttn, &LLAMA3_70B, 16384, world)),
    ];
    for (name, op) in cases {
        let (np, npar) = baselines::plan(Baseline::KernelLevel, &op, &topo)?;
        let native = simulate(&np, &topo, npar)?.makespan_us;
        let tuned = autotune::tune(&op, &topo, Budget::Quick)?;
        println!(
            "  {name} native {:>10}  +syncopate {:>10}  ({:.2}x, best: {})",
            fmt_us(native),
            fmt_us(tuned.makespan_us),
            native / tuned.makespan_us,
            tuned.cfg.label()
        );
    }
    Ok(())
}
