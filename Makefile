# Build-time artifact pipeline + convenience wrappers.

.PHONY: artifacts build test bench fmt clippy clean

# AOT-lower every L2 entry point to HLO text + manifest (needs jax).
artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts

build:
	cd rust && cargo build --release

# Tier-1 verification. Clean on a bare checkout: tests that need the AOT
# artifacts skip with a message until `make artifacts` has run.
test:
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && cargo bench --bench hotpath

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy --all-targets -- -D warnings

clean:
	cd rust && cargo clean
