# Build-time artifact pipeline + convenience wrappers.

.PHONY: artifacts build test bench fmt clippy clean examples lint-plans lint-topos trace-smoke obs-smoke flight-smoke perf-smoke

# AOT-lower every L2 entry point to HLO text + manifest (needs jax).
artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts

build:
	cd rust && cargo build --release

# Tier-1 verification. Clean on a bare checkout: tests that need the AOT
# artifacts skip with a message until `make artifacts` has run.
test:
	cd rust && cargo build --release && cargo test -q

# Hot-path microbench; also writes machine-readable BENCH_results.json at
# the repo root (override the path with BENCH_RESULTS=...).
bench:
	cd rust && cargo bench --bench hotpath

# Run the example binaries (living documentation; also exercised in CI).
examples:
	cd rust && cargo run --release --example custom_schedule && cargo run --release --example quickstart

# Lint the shipped .sched plan corpus (parse + validate + round-trip).
lint-plans:
	cd rust && cargo run --release -- plan lint ../examples/plans/*.sched

# Lint the shipped .topo hardware descriptions (parse + round-trip +
# instantiate).
lint-topos:
	cd rust && cargo run --release -- topo lint ../examples/topos/*.topo

# The sim<->execution loop end to end: trace a case, analyze the overlap,
# calibrate a .topo from the measurements, lint + run on it (DESIGN.md §14).
trace-smoke:
	cd rust && cargo run --release -- exec --case tp-block --world 2 --trace /tmp/syncopate_trace.json
	cd rust && cargo run --release -- trace overlap /tmp/syncopate_trace.json
	cd rust && cargo run --release -- calibrate --from /tmp/syncopate_trace.json --topo h100_node -o /tmp/syncopate_cal.topo
	cd rust && cargo run --release -- topo lint /tmp/syncopate_cal.topo

# Telemetry end to end: repeat-run feeding histograms, stats snapshot
# export + schema check, live serving stats from a worker pool (§16).
obs-smoke:
	cd rust && cargo run --release -- exec --case ag-gemm --world 2 --repeat 5 --stats /tmp/syncopate_stats.json
	cd rust && cargo run --release -- stats show /tmp/syncopate_stats.json
	cd rust && cargo run --release -- stats check /tmp/syncopate_stats.json
	cd rust && cargo run --release -- serve-demo --workers 4 --stats /tmp/syncopate_serve.json

# Post-mortem capture end to end: a known runtime deadlock writes a
# flight dump whose verdict carries the stuck ranks' recent events,
# the dump round-trips through `flight show`, and sampled live tracing
# feeds the divergence gauge (§18).
flight-smoke:
	cd rust && cargo run --release -- flight dump --deadlock-demo --out /tmp/syncopate_flight.json --chrome /tmp/syncopate_flight_chrome.json
	cd rust && cargo run --release -- flight show /tmp/syncopate_flight.json
	cd rust && cargo run --release -- serve-demo --workers 4 --trace-sample 4 --stats /tmp/syncopate_flight_serve.json
	cd rust && cargo run --release -- stats check /tmp/syncopate_flight_serve.json

# The perf toolchain end to end (§19): profile a captured trace's
# critical path (table + JSON + painted Chrome overlay + what-if bound),
# record a noise-aware baseline, and gate a re-run against it — a self-gate
# at an advisory threshold must pass. Baselines/trajectory land at the
# repo root (BENCH_baseline.json / BENCH_results.json).
perf-smoke:
	cd rust && cargo run --release -- exec --case tp-block --world 2 --trace /tmp/syncopate_perf_trace.json
	cd rust && cargo run --release -- perf critical /tmp/syncopate_perf_trace.json --chrome /tmp/syncopate_perf_overlay.json --what-if-comm-x 0.5
	cd rust && cargo run --release -- perf critical /tmp/syncopate_perf_trace.json --json > /tmp/syncopate_perf_critical.json
	cd rust && cargo run --release -- perf record --cases tp-block,ag-gemm --world 2 --repeat 5 --out ../BENCH_baseline.json --bench ../BENCH_results.json
	cd rust && cargo run --release -- perf gate --baseline ../BENCH_baseline.json --cases tp-block,ag-gemm --world 2 --repeat 5 --max-regress 25

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy --all-targets -- -D warnings

clean:
	cd rust && cargo clean
